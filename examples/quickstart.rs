//! Quickstart: the whole framework on a tiny synthetic trace, in memory.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --divisor 500]
//! ```
//!
//! Generates a miniature curation-workflow provenance trace, preprocesses
//! it (WCC → Algorithm 3 partitioning → set dependencies), opens a
//! [`ProvSession`] over the result and answers the same lineage query with
//! all three engines through the uniform `ProvenanceEngine` interface —
//! showing they agree while their `QueryStats` reveal very different data
//! volumes. Finishes with the `Auto` router and a batched `query_many`.

use provspark::config::EngineConfig;
use provspark::harness::{select_queries, EngineRouter, ProvSession, QueryClass};
use provspark::provenance::query::QueryRequest;
use provspark::util::fmt::human_duration;
use provspark::workflow::generator::{generate, GeneratorConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = provspark::cli::Args::parse_env(&[])?;
    let divisor: usize = args.get_parsed_or("divisor", 500)?;

    // 1. Generate a small trace (default ~1/500 of the paper's base).
    let gen = GeneratorConfig { scale_divisor: divisor, ..Default::default() };
    let (trace, graph, splits) = generate(&gen);
    println!("trace: {} triples, {} nodes", trace.len(), trace.node_count());

    // 2. Preprocess: components, sets (θ scaled), set dependencies.
    let theta = (25_000 / divisor.max(1)).max(50);
    let pre = provspark::provenance::pipeline::preprocess(
        &trace,
        &graph,
        &splits,
        theta,
        100,
        provspark::provenance::pipeline::WccImpl::Driver,
    );
    println!(
        "preprocess: {} components ({} large), {} sets, {} set-deps",
        pre.component_count,
        pre.large_components.len(),
        pre.set_count,
        pre.set_deps.len()
    );

    // 3. Open a query session. The session owns all three engines over the
    //    Arc-shared data (no copies of the trace) and routes requests.
    let mut cfg = EngineConfig::default();
    cfg.prov.tau = 5_000; // collect-to-driver threshold
    let session = ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre))?;

    // 4. Query the lineage of a deep derived value in the largest component
    //    (the LC-SL class of §4) on every engine, via typed requests.
    let q = select_queries(&session.trace(), &session.pre(), QueryClass::LcSl, 1, divisor, 42)?
        .items[0];
    let req = QueryRequest::new(q);
    let mut first = None;
    for router in [EngineRouter::Rq, EngineRouter::CcProv, EngineRouter::CsProv] {
        let resp = session.execute_on(router, &req);
        println!(
            "{:6}: {} ancestors via {} transformations in {:>8}",
            resp.stats.engine,
            resp.lineage.ancestors.len(),
            resp.lineage.transformation_count(),
            human_duration(resp.stats.total_time()),
        );
        println!("        {}", resp.stats.summary());
        if let Some(prev) = &first {
            assert_eq!(prev, &resp.lineage, "engines must agree");
        } else {
            first = Some(resp.lineage);
        }
    }
    println!("all engines agree; CSProv touches the least data.");

    // 5. The Auto router sends each query to the cheapest engine, and
    //    query_many fans a batch across the worker pool.
    let auto = session.execute(&req);
    println!("auto router picked: {}", auto.stats.engine);
    let batch: Vec<QueryRequest> = select_queries(
        &session.trace(),
        &session.pre(),
        QueryClass::ScSl,
        3,
        divisor,
        7,
    )?
    .items
    .iter()
    .map(|&item| QueryRequest::new(item))
    .collect();
    let responses = session.query_many(&batch);
    println!(
        "batched {} SC-SL queries: engines used = {:?}",
        responses.len(),
        responses.iter().map(|r| r.stats.engine).collect::<Vec<_>>(),
    );
    Ok(())
}
