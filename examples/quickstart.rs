//! Quickstart: the whole framework on a tiny synthetic trace, in memory.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --divisor 500]
//! ```
//!
//! Generates a miniature curation-workflow provenance trace, preprocesses
//! it (WCC → Algorithm 3 partitioning → set dependencies), opens a
//! [`ProvSession`] over the result and answers the same lineage query with
//! all three engines through the uniform `ProvenanceEngine` interface —
//! showing they agree while their `QueryStats` reveal very different data
//! volumes. Finishes with the `Auto` router and a batched `query_many`,
//! and — with `--shards N` — proves a component-space [`ShardedSession`]
//! answers every query identically to the unsharded session (the CI
//! sharded smoke test runs this with `--shards 4`). With `--fault-plan`
//! (e.g. `panic:shuffle:0.05,seed=6`) deterministic faults are injected
//! into the cluster's tasks and absorbed by the retrying supervisor
//! (budget: `--task-retries`) — every assertion still holds, which is the
//! CI fault-injection smoke test. With `--memory-budget` (e.g. `4k`) the
//! engines spill their datasets to segment files and page partitions back
//! through the byte-budgeted cache — the out-of-core CI smoke test runs
//! this with a budget far below the working set and every equivalence
//! assertion must still hold.
//!
//! [`ShardedSession`]: provspark::harness::ShardedSession

use provspark::config::EngineConfig;
use provspark::harness::{
    select_queries, EngineRouter, ProvSession, QueryClass, ShardedSession,
};
use provspark::provenance::query::QueryRequest;
use provspark::util::fmt::human_duration;
use provspark::workflow::generator::{generate, GeneratorConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = provspark::cli::Args::parse_env(&[])?;
    let divisor: usize = args.get_parsed_or("divisor", 500)?;
    let shards: usize = args.get_parsed_or("shards", 1)?;
    let fault_plan = args
        .get("fault-plan")
        .map(|s| s.parse::<provspark::fault::FaultPlan>())
        .transpose()?;
    let task_retries: u32 = args.get_parsed_or("task-retries", 2)?;
    let memory_budget = args
        .get("memory-budget")
        .map(provspark::config::parse_bytes)
        .transpose()?
        .unwrap_or(0);

    // 1. Generate a small trace (default ~1/500 of the paper's base).
    let gen = GeneratorConfig { scale_divisor: divisor, ..Default::default() };
    let (trace, graph, splits) = generate(&gen);
    println!("trace: {} triples, {} nodes", trace.len(), trace.node_count());

    // 2. Preprocess: components, sets (θ scaled), set dependencies.
    let theta = (25_000 / divisor.max(1)).max(50);
    let pre = provspark::provenance::pipeline::preprocess(
        &trace,
        &graph,
        &splits,
        theta,
        100,
        provspark::provenance::pipeline::WccImpl::Driver,
    );
    println!(
        "preprocess: {} components ({} large), {} sets, {} set-deps",
        pre.component_count,
        pre.large_components.len(),
        pre.set_count,
        pre.set_deps.len()
    );

    // 3. Open a query session. The session owns all three engines over the
    //    Arc-shared data (no copies of the trace) and routes requests.
    let mut cfg = EngineConfig::default();
    cfg.prov.tau = 5_000; // collect-to-driver threshold
    cfg.cluster.fault_plan = fault_plan;
    cfg.cluster.task_retries = task_retries;
    cfg.cluster.memory_budget = memory_budget;
    let (trace, pre) = (Arc::new(trace), Arc::new(pre));
    let session = ProvSession::new(&cfg, Arc::clone(&trace), Arc::clone(&pre))?;

    // 4. Query the lineage of a deep derived value in the largest component
    //    (the LC-SL class of §4) on every engine, via typed requests.
    let q = select_queries(&session.trace(), &session.pre(), QueryClass::LcSl, 1, divisor, 42)?
        .items[0];
    let req = QueryRequest::new(q);
    let mut first = None;
    for router in [EngineRouter::Rq, EngineRouter::CcProv, EngineRouter::CsProv] {
        let resp = session.execute_on(router, &req);
        println!(
            "{:6}: {} ancestors via {} transformations in {:>8}",
            resp.stats.engine,
            resp.lineage.ancestors.len(),
            resp.lineage.transformation_count(),
            human_duration(resp.stats.total_time()),
        );
        println!("        {}", resp.stats.summary());
        if let Some(prev) = &first {
            assert_eq!(prev, &resp.lineage, "engines must agree");
        } else {
            first = Some(resp.lineage);
        }
    }
    println!("all engines agree; CSProv touches the least data.");

    // 5. The Auto router sends each query to the cheapest engine, and
    //    query_many fans a batch across the worker pool.
    let auto = session.execute(&req);
    println!("auto router picked: {}", auto.stats.engine);
    let batch: Vec<QueryRequest> = select_queries(
        &session.trace(),
        &session.pre(),
        QueryClass::ScSl,
        3,
        divisor,
        7,
    )?
    .items
    .iter()
    .map(|&item| QueryRequest::new(item))
    .collect();
    let responses = session.query_many(&batch);
    println!(
        "batched {} SC-SL queries: engines used = {:?}",
        responses.len(),
        responses.iter().map(|r| r.stats.engine).collect::<Vec<_>>(),
    );

    // 6. Optional: shard the component space and prove the scatter-gather
    //    front is invisible to queries — identical lineages and routing on
    //    every request above.
    if shards > 1 {
        let sharded = ShardedSession::new(&cfg, trace, pre, shards)?;
        let mut reqs: Vec<QueryRequest> = vec![req.clone()];
        reqs.extend(batch.iter().cloned());
        let mut auto_report = None;
        for router in
            [EngineRouter::Auto, EngineRouter::Rq, EngineRouter::CcProv, EngineRouter::CsProv]
        {
            let a = session.query_many_on(router, &reqs);
            let (b, report) = sharded.query_many_report_on(router, &reqs);
            for ((r, ra), rb) in reqs.iter().zip(&a).zip(&b) {
                assert_eq!(
                    ra.lineage, rb.lineage,
                    "sharded answer diverges (router {router}, item {})",
                    r.item
                );
                assert_eq!(
                    ra.stats.engine, rb.stats.engine,
                    "sharded routing diverges (router {router}, item {})",
                    r.item
                );
            }
            if router == EngineRouter::Auto {
                auto_report = Some(report);
            }
        }
        println!(
            "sharded x{shards}: all {} answers match the unsharded session",
            reqs.len()
        );
        print!("{}", auto_report.expect("Auto ran first").summary());
    }

    // 7. Supervision report: with --fault-plan, injected task faults were
    //    absorbed by the retrying supervisor — the assertions above prove
    //    the answers are unaffected.
    if let Some(inj) = session.context().fault() {
        let m = session.context().metrics().snapshot();
        println!(
            "fault injection ({}): {} fault(s) fired, {} task retry(ies) absorbed",
            inj.plan(),
            inj.fired(),
            m.tasks_retried,
        );
    }

    // 8. Out-of-core report: with --memory-budget, every answer above was
    //    served through the spill-and-page path — the same assertions
    //    prove paging is invisible to queries.
    if memory_budget > 0 {
        let m = session.context().metrics().snapshot();
        assert!(m.bytes_spilled > 0, "a budgeted session must spill its engine datasets");
        println!(
            "out-of-core (budget {}): {}",
            provspark::util::fmt::human_bytes(memory_budget),
            m.summary(),
        );
    }
    Ok(())
}
