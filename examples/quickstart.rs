//! Quickstart: the whole framework on a tiny synthetic trace, in memory.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a miniature curation-workflow provenance trace, preprocesses
//! it (WCC → Algorithm 3 partitioning → set dependencies), and answers the
//! same lineage query with all three engines — RQ, CCProv, CSProv —
//! showing they agree while touching very different data volumes.

use provspark::config::EngineConfig;
use provspark::harness::EngineSet;
use provspark::minispark::MiniSpark;
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::util::fmt::human_duration;
use provspark::workflow::generator::{generate, GeneratorConfig};

fn main() -> anyhow::Result<()> {
    // 1. Generate a small trace (~1/500 of the paper's base).
    let gen = GeneratorConfig { scale_divisor: 500, ..Default::default() };
    let (trace, graph, splits) = generate(&gen);
    println!("trace: {} triples, {} nodes", trace.len(), trace.node_count());

    // 2. Preprocess: components, sets (θ scaled), set dependencies.
    let theta = (25_000 / gen.scale_divisor.max(1)).max(400);
    let pre = preprocess(&trace, &graph, &splits, theta, 100, WccImpl::Driver);
    println!(
        "preprocess: {} components ({} large), {} sets, {} set-deps",
        pre.component_count,
        pre.large_components.len(),
        pre.set_count,
        pre.set_deps.len()
    );

    // 3. Build the engines (embedded minispark cluster).
    let mut cfg = EngineConfig::default();
    cfg.prov.tau = 5_000; // collect-to-driver threshold
    let sc = MiniSpark::new(cfg.cluster.clone());
    let engines = EngineSet::build(&sc, &trace, &pre, &cfg)?;

    // 4. Query the lineage of a deep derived value in the largest component
    //    (the LC-SL class of §4).
    let q = provspark::harness::select_queries(
        &trace,
        &pre,
        provspark::harness::QueryClass::LcSl,
        1,
        gen.scale_divisor,
        42,
    )?
    .items[0];

    for (name, f) in [
        ("RQ    ", Box::new(|q| engines.rq.query(q)) as Box<dyn Fn(u64) -> _>),
        ("CCProv", Box::new(|q| engines.ccprov.query(q))),
        ("CSProv", Box::new(|q| engines.csprov.query(q))),
    ] {
        let before = sc.metrics().snapshot();
        let (lineage, dur) = provspark::util::timer::time_it(|| f(q));
        let delta = sc.metrics().snapshot().since(&before);
        println!(
            "{name}: {} ancestors via {} transformations in {:>8}  (rows scanned: {})",
            lineage.ancestors.len(),
            lineage.transformation_count(),
            human_duration(dur),
            delta.rows_scanned,
        );
    }
    println!("all engines agree; CSProv touches the least data. See DESIGN.md.");
    Ok(())
}
