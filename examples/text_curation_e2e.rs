//! End-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real small workload —
//!
//! 1. generate the curation-workflow provenance trace (the paper's §4
//!    dataset, scaled),
//! 2. preprocess with all available WCC backends (driver union-find,
//!    distributed minispark label propagation, and the AOT-compiled
//!    XLA/PJRT fixpoint — L1 Pallas kernel inside an L2 while-loop),
//!    cross-checking their outputs,
//! 3. partition large components (Algorithm 3) and print Table 9,
//! 4. answer all three query classes with RQ / CCProv / CSProv and print
//!    the Tables 10–12-shaped rows plus the headline speedups.
//!
//! ```bash
//! cargo run --release --example text_curation_e2e [-- --divisor 10 --replications 1,4]
//! ```

use provspark::cli::Args;
use provspark::harness::{
    component_census, drilldown_report, query_table, select_queries, table9,
    ExperimentConfig, ProvSession, QueryClass,
};
use provspark::minispark::MiniSpark;
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::provenance::wcc::{wcc_driver, wcc_minispark};
use provspark::runtime::{xla_wcc, XlaRuntime};
use provspark::util::fmt::{human_count, human_duration};
use provspark::util::timer::time_it;
use provspark::workflow::generator::{generate, GeneratorConfig, TraceStats};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(&[])?;
    let divisor: usize = args.get_parsed_or("divisor", 10)?;
    let reps: Vec<usize> = args
        .get_or("replications", "1,4")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    println!("=== provspark end-to-end: text-curation workflow (divisor {divisor}) ===\n");

    // ---- 1. workload ------------------------------------------------------
    let gen = GeneratorConfig { scale_divisor: divisor, ..Default::default() };
    let ((trace, graph, splits), t_gen) = time_it(|| generate(&gen));
    let stats = TraceStats::compute(&trace, 20, (25_000 / divisor).max(50));
    println!("[1] generated in {}: {}", human_duration(t_gen), stats.summary());

    // ---- 2. WCC: all three backends must agree ---------------------------
    let (labels_driver, t_uf) = time_it(|| wcc_driver(&trace));
    println!("\n[2] WCC driver union-find     : {}", human_duration(t_uf));

    let sc = MiniSpark::local();
    let (labels_ms, t_ms) = time_it(|| wcc_minispark(&sc, &trace, 32));
    println!("    WCC minispark label-prop  : {}", human_duration(t_ms));
    assert_eq!(labels_driver, labels_ms, "minispark WCC disagrees with union-find");

    match XlaRuntime::new(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            let (labels_xla, t_xla) = time_it(|| xla_wcc(&rt, &trace));
            match labels_xla {
                Ok(l) => {
                    println!("    WCC XLA/PJRT fixpoint     : {}", human_duration(t_xla));
                    assert_eq!(labels_driver, l, "XLA WCC disagrees with union-find");
                }
                Err(e) => println!("    WCC XLA skipped: {e}"),
            }
        }
        Err(e) => println!("    WCC XLA skipped (no artifacts): {e}"),
    }
    println!("    all available WCC backends agree ✓");

    // ---- 3. Algorithm 3 + Table 9 ----------------------------------------
    let theta = (25_000 / divisor).max(50);
    let big = (1000 / divisor).max(20);
    let (pre, t_pre) =
        time_it(|| preprocess(&trace, &graph, &splits, theta, big, WccImpl::Driver));
    println!(
        "\n[3] preprocess in {}: {} sets, {} set-deps",
        human_duration(t_pre),
        human_count(pre.set_count as u64),
        human_count(pre.set_deps.len() as u64)
    );
    table9(&pre).print();
    component_census(&pre).print();

    // ---- 4. Tables 10–12 ---------------------------------------------------
    let mut xcfg = ExperimentConfig::for_divisor(divisor);
    xcfg.replications = reps;
    xcfg.queries_per_class = 5;
    println!("\n[4] query tables (engines: RQ / CCProv / CSProv)");
    let mut headline: Vec<(QueryClass, f64, f64)> = Vec::new();
    for class in [QueryClass::ScSl, QueryClass::LcSl, QueryClass::LcLl] {
        let (table, raw) = query_table(class, &xcfg)?;
        table.print();
        if let Some(&(_, rq, cc, cs)) = raw.last() {
            let cs = cs.max(1e-9);
            headline.push((class, rq / cs, cc / cs));
        }
    }

    // ---- 5. drill-down + headline -----------------------------------------
    let session = ProvSession::new(&xcfg.engine, Arc::new(trace), Arc::new(pre))?;
    let sel = select_queries(&session.trace(), &session.pre(), QueryClass::LcLl, 1, divisor, 42)?;
    println!("\n[5] point-query drill-down (LC-LL):");
    print!("{}", drilldown_report(&session, sel.items[0]));

    println!("\n=== headline (largest scale) ===");
    for (class, rq_x, cc_x) in headline {
        println!("{class}: CSProv is {rq_x:.1}× faster than RQ, {cc_x:.1}× faster than CCProv");
    }
    Ok(())
}
