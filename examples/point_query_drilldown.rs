//! Reproduces the paper's §4 "Discussion": for one point query per class,
//! report the connected set, its set-lineage, and the minimal data volume
//! CSProv recurses over vs. what CCProv / RQ must process (the paper's
//! "4177 triples vs 2.7M" argument).
//!
//! ```bash
//! cargo run --release --example point_query_drilldown [-- --divisor 10]
//! ```

use provspark::cli::Args;
use provspark::harness::{drilldown_report, select_queries, ProvSession, QueryClass};
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::workflow::generator::{generate, GeneratorConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(&[])?;
    let divisor: usize = args.get_parsed_or("divisor", 10)?;
    let (trace, graph, splits) =
        generate(&GeneratorConfig { scale_divisor: divisor, ..Default::default() });
    let theta = (25_000 / divisor).max(50);
    let pre = preprocess(&trace, &graph, &splits, theta, (1000 / divisor).max(20), WccImpl::Driver);
    let cfg = provspark::config::EngineConfig::default();
    let session = ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre))?;

    for class in [QueryClass::ScSl, QueryClass::LcSl, QueryClass::LcLl] {
        let sel = select_queries(&session.trace(), &session.pre(), class, 1, divisor, 42)?;
        println!("--- {class} (ancestors in [{}, {}]) ---", sel.band.0, sel.band.1);
        print!("{}", drilldown_report(&session, sel.items[0]));
        println!();
    }
    println!(
        "note: for SC-SL the set-lineage is empty (small components are managed\n\
         as single sets) and CSProv reduces to CCProv, as §2.3 predicts."
    );
    Ok(())
}
