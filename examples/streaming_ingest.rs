//! Streaming ingestion: a query session absorbing provenance while it
//! serves traffic.
//!
//! Generates a base trace, opens a [`ProvSession`], then replays the rest
//! of the trace as a stream of [`TripleBatch`] deltas. After every batch
//! the session's engines have absorbed the delta (epoch swap — no full
//! re-preprocess, no engine rebuild), and a probe query shows its lineage
//! growing as new derivations arrive.
//!
//! ```bash
//! cargo run --release --example streaming_ingest -- --divisor 200 --batches 4
//! ```

use provspark::cli::Args;
use provspark::config::EngineConfig;
use provspark::harness::ProvSession;
use provspark::provenance::incremental::TripleBatch;
use provspark::provenance::model::Trace;
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::provenance::query::QueryRequest;
use provspark::util::fmt::{human_count, human_duration};
use provspark::util::timer::time_it;
use provspark::workflow::generator::{generate, GeneratorConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(&[])?;
    let divisor: usize = args.get_parsed_or("divisor", 200)?;
    let batches: usize = args.get_parsed_or("batches", 4)?;
    let theta = (25_000 / divisor).max(50);

    // 1. The full stream, of which 60% is "history" and 40% arrives live.
    let (full, graph, splits) =
        generate(&GeneratorConfig { scale_divisor: divisor, ..Default::default() });
    let cut = full.len() * 6 / 10;
    let base = Trace::new(full.triples[..cut].to_vec());
    println!(
        "history: {} triples; live stream: {} triples in {batches} batches",
        human_count(cut as u64),
        human_count((full.len() - cut) as u64),
    );

    // 2. Preprocess the history once, open the session.
    let big = (1000 / divisor).max(20);
    let (pre, d) = time_it(|| preprocess(&base, &graph, &splits, theta, big, WccImpl::Driver));
    println!("initial preprocess: {}", human_duration(d));
    let mut cfg = EngineConfig::default();
    cfg.prov.tau = 5_000;
    let session = ProvSession::new(&cfg, Arc::new(base), Arc::new(pre))?;

    // A probe item from the history — we watch its lineage grow.
    let probe = full.triples[cut / 2].dst.raw();
    let before = session.execute(&QueryRequest::new(probe));
    println!(
        "probe {probe}: {} ancestors before ingestion (epoch {})",
        before.lineage.ancestors.len(),
        session.epoch(),
    );

    // 3. Replay the rest as deltas. Each ingest applies the batch to the
    //    incremental index (cost ∝ delta + dirty components) and swaps the
    //    engine epoch; queries in flight keep their epoch.
    let rest = &full.triples[cut..];
    let chunk = rest.len().div_ceil(batches.max(1));
    for (i, window) in rest.chunks(chunk).enumerate() {
        let (stats, d) =
            time_it(|| session.ingest(&TripleBatch::new(window.to_vec())));
        let stats = stats?;
        println!(
            "batch {}: {} triples in {} — {}",
            i + 1,
            human_count(window.len() as u64),
            human_duration(d),
            stats.summary(),
        );
    }

    // 4. The same probe now sees every derivation the stream delivered.
    let after = session.execute(&QueryRequest::new(probe));
    println!(
        "probe {probe}: {} ancestors after ingestion (epoch {}, {} triples indexed, engine {})",
        after.lineage.ancestors.len(),
        session.epoch(),
        human_count(session.trace().len() as u64),
        after.stats.engine,
    );
    assert!(after.lineage.ancestors.len() >= before.lineage.ancestors.len());
    assert_eq!(session.epoch(), rest.chunks(chunk).count() as u64);
    println!("session served queries across {} epochs without a rebuild.", session.epoch() + 1);
    Ok(())
}
