//! Compliance / data-quality audit scenario (the paper's §1 motivation:
//! "if the value of a data-item is erroneous, we can examine its lineage
//! to investigate which transformation has introduced the error").
//!
//! A curator flags a knowledge-base value as wrong. This example:
//!
//! 1. traces its full lineage with CSProv (real-time even inside a large
//!    component),
//! 2. ranks the transformations on the lineage paths and reports the one
//!    closest to the flagged value (the repair candidate),
//! 3. computes the *blast radius*: every downstream value derived from the
//!    suspect transformation's outputs (forward closure — the GDPR
//!    "right to erasure" propagation set).
//!
//! ```bash
//! cargo run --release --example gdpr_audit
//! ```

use provspark::config::EngineConfig;
use provspark::harness::{select_queries, ProvSession, QueryClass};
use provspark::provenance::model::ProvTriple;
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
use provspark::provenance::query::QueryRequest;
use provspark::util::fmt::human_duration;
use provspark::util::ids::AttrValueId;
use provspark::workflow::generator::{generate, GeneratorConfig};
use rustc_hash::FxHashMap;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let divisor = 50;
    let (trace, graph, splits) =
        generate(&GeneratorConfig { scale_divisor: divisor, ..Default::default() });
    let theta = (25_000 / divisor).max(50);
    let pre = preprocess(&trace, &graph, &splits, theta, 100, WccImpl::Driver);
    let cfg = EngineConfig::default();
    let session = ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre))?;
    let (trace, pre) = (session.trace(), session.pre());

    // The "flagged" value: a deep-lineage item in the largest component.
    let flagged = select_queries(&trace, &pre, QueryClass::LcLl, 1, divisor, 7)?.items[0];
    println!("audit: flagged value {} ({})", flagged, AttrValueId(flagged));

    // 1. Lineage: who contributed to this value? The Auto router sends a
    //    large-component item to CSProv; the stats prove the minimal touch.
    let resp = session.execute(&QueryRequest::new(flagged));
    let lineage = resp.lineage.clone();
    println!(
        "lineage: {} ancestors across {} transformations ({})",
        lineage.ancestors.len(),
        lineage.transformation_count(),
        human_duration(resp.stats.total_time())
    );
    println!("  via {}", resp.stats.summary());

    // 2. Suspect transformation: the op on the edges *into* the flagged
    //    value (the last derivation step), plus a contribution ranking.
    let mut op_edges: FxHashMap<u32, usize> = FxHashMap::default();
    for t in &lineage.triples {
        *op_edges.entry(t.op.0).or_default() += 1;
    }
    let mut last_ops: Vec<u32> = lineage
        .triples
        .iter()
        .filter(|t| t.dst.raw() == flagged)
        .map(|t| t.op.0)
        .collect();
    last_ops.sort_unstable();
    last_ops.dedup();
    let op_name = |op: u32| {
        let e = graph.edges()[op as usize];
        format!("{} → {}", graph.name_of(e.parent), graph.name_of(e.child))
    };
    println!("suspect transformation(s) feeding the flagged value:");
    for op in &last_ops {
        println!("  op{} [{}] — primary repair candidate", op, op_name(*op));
    }
    let mut ranked: Vec<(u32, usize)> = op_edges.into_iter().collect();
    ranked.sort_by_key(|&(_, n)| std::cmp::Reverse(n));
    println!("transformations by lineage contribution:");
    for (op, n) in ranked.iter().take(5) {
        println!("  op{op} [{}]: {n} derivation edges", op_name(*op));
    }

    // 3. Blast radius: forward closure — reuse the ancestor closure on the
    //    *reversed* component graph. The flagged KB value is usually a
    //    sink, so the erasure set is computed for the deepest *input*
    //    ancestor (the GDPR case: a personal datum in a source document
    //    must be erased along with everything derived from it).
    let cc = pre.cc_of[&flagged];
    let comp: Vec<ProvTriple> = trace
        .triples
        .iter()
        .filter(|t| pre.cc_of[&t.src.raw()] == cc)
        .copied()
        .collect();
    let derived: rustc_hash::FxHashSet<u64> = comp.iter().map(|t| t.dst.raw()).collect();
    let erase = lineage
        .ancestors
        .iter()
        .copied()
        .find(|a| !derived.contains(a)) // a source value in the lineage
        .unwrap_or(flagged);
    println!(
        "erasure request: source value {} ({})",
        erase,
        AttrValueId(erase)
    );
    let reversed: Vec<ProvTriple> =
        comp.iter().map(|t| ProvTriple::new(t.dst, t.src, t.op)).collect();
    let (blast, dur2) =
        provspark::util::timer::time_it(|| NativeClosure.closure(&reversed, erase));
    println!(
        "blast radius: {} downstream values would need re-derivation ({})",
        blast.ancestors.len(),
        human_duration(dur2)
    );
    // Per-entity breakdown tells the curator which tables to re-run.
    let mut by_entity: FxHashMap<u16, usize> = FxHashMap::default();
    for &v in &blast.ancestors {
        *by_entity.entry(AttrValueId(v).entity().0).or_default() += 1;
    }
    let mut by_entity: Vec<(u16, usize)> = by_entity.into_iter().collect();
    by_entity.sort_by_key(|&(_, n)| std::cmp::Reverse(n));
    println!("affected tables:");
    for (e, n) in by_entity.iter().take(6) {
        println!(
            "  {}: {n} values",
            graph.name_of(provspark::util::ids::EntityId(*e))
        );
    }
    Ok(())
}
