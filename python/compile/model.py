"""L2 — the JAX compute graph: relaxation fixpoints built on the L1 Pallas
kernel.

One artifact family serves both dense phases of the system (see
kernels/label_prop.py): ``relax_fixpoint(labels0, parents)`` iterates the
Pallas relaxation step inside a ``lax.while_loop`` until no label changes,
entirely inside one compiled HLO module — the Rust runtime calls it once
per WCC preprocessing pass / per driver-side ancestor closure, with no
host round-trips in the loop.

Carried state is just ``(labels, changed)``; ``parents`` is a loop
invariant, so XLA keeps it resident and the loop body is the kernel plus a
reduction — no recomputation of static data (the L2 optimization target
from DESIGN.md §7).
"""

import jax
import jax.numpy as jnp

from .kernels.label_prop import relax_step


def relax_fixpoint(labels0: jax.Array, parents: jax.Array) -> tuple[jax.Array]:
    """Iterate ``relax_step`` to fixpoint.

    labels0: int32[N] initial labels; parents: int32[N, K] padded pull
    matrix. Returns a 1-tuple (lowered with ``return_tuple=True``; the Rust
    side unwraps with ``to_tuple1``).
    """

    def cond(state):
        _, changed = state
        return changed > 0

    def body(state):
        labels, _ = state
        new = relax_step(labels, parents)
        changed = jnp.sum((new != labels).astype(jnp.int32))
        return new, changed

    labels, _ = jax.lax.while_loop(cond, body, (labels0, jnp.int32(1)))
    return (labels,)


def wcc_labels_from_parents(parents: jax.Array) -> tuple[jax.Array]:
    """WCC entry point: labels start as iota, fixpoint = component minima."""
    n = parents.shape[0]
    return relax_fixpoint(jnp.arange(n, dtype=jnp.int32), parents)


def reach_labels(parents: jax.Array, query: jax.Array) -> tuple[jax.Array]:
    """Ancestor-closure entry point.

    ``parents`` is the *children* pull matrix of the provenance DAG;
    ``query`` is the dense index of the queried node. Labels start at 1
    everywhere except 0 at the query; the fixpoint is 0 exactly on
    ``{query} ∪ ancestors(query)``.
    """
    n = parents.shape[0]
    labels0 = jnp.ones((n,), dtype=jnp.int32).at[query].set(0)
    return relax_fixpoint(labels0, parents)
