"""Pure-jnp/numpy oracles for the Pallas kernels — the build-time
correctness signal (pytest compares kernel vs. these)."""

import numpy as np


def ref_relax_step(labels: np.ndarray, parents: np.ndarray) -> np.ndarray:
    """new[i] = min(labels[i], min_k labels[parents[i, k]])."""
    gathered = labels[parents]  # (N, K)
    return np.minimum(labels, gathered.min(axis=1)).astype(np.int32)


def ref_relax_fixpoint(labels0: np.ndarray, parents: np.ndarray) -> np.ndarray:
    """Iterate ref_relax_step until no label changes."""
    labels = labels0.astype(np.int32)
    while True:
        new = ref_relax_step(labels, parents)
        if (new == labels).all():
            return new
        labels = new


def ref_wcc_labels(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    """Union-find oracle: label = min node index in the component."""
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    mins: dict[int, int] = {}
    for v in range(n):
        r = find(v)
        mins[r] = min(mins.get(r, v), v)
    return np.array([mins[find(v)] for v in range(n)], dtype=np.int32)


def parents_matrix_from_edges(
    n: int, edges: list[tuple[int, int]], k: int, directed: bool = False
) -> tuple[np.ndarray, int]:
    """Build the padded pull-neighbor matrix, chaining virtual nodes for
    rows that overflow K slots (mirrors rust/src/runtime/remap.rs).

    Undirected (WCC): each edge lands in both endpoint rows.
    Directed (closure): edge (src, dst) lands in src's row only — src pulls
    its *children*, so reached-ness flows child → parent.

    Returns (matrix[int32, (n_total, k)], n_total) where rows are padded
    with self-indices and n_total >= n includes virtual nodes.
    """
    assert k >= 2, "need K >= 2 to chain overflow rows"
    neigh: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges:
        neigh[a].append(b)
        if not directed:
            neigh[b].append(a)

    # Pull semantics: row(v) lists the nodes whose labels v takes a min
    # over. Chaining only needs the *pulling* direction — for undirected
    # graphs the reverse flow exists because each edge is in both rows.
    rows: list[list[int]] = []
    for v in range(n):
        ns = neigh[v]
        rows.append(ns[: k - 1] if len(ns) > k else list(ns))
    for v in range(n):
        rest = neigh[v][k - 1 :] if len(neigh[v]) > k else []
        prev = v
        while rest:
            virt = len(rows)
            rows[prev].append(virt)  # prev pulls the virtual conduit
            take = min(k - 1, len(rest))
            rows.append(rest[:take])
            rest = rest[take:]
            prev = virt

    n_total = len(rows)
    mat = np.empty((n_total, k), dtype=np.int32)
    for i, row in enumerate(rows):
        assert len(row) <= k, (i, len(row))
        padded = row + [i] * (k - len(row))
        mat[i] = padded
    return mat, n_total
