"""L1 — Pallas kernel: blocked min-label relaxation over a padded
pull-neighbor matrix.

This is the dense hot-spot of the paper's preprocessing (weakly connected
components, §2.2) *and* of the driver-side ancestor closure: both are
fixpoints of the same relaxation

    new_label[i] = min(label[i], min_k label[parents[i, k]])

* For WCC, ``parents`` holds the (undirected) neighbor lists and labels
  start as ``iota(N)``; the fixpoint labels every node with the minimum
  node index in its component.
* For the ancestor closure, ``parents`` holds each node's *children* in the
  provenance DAG and labels start as ``1`` everywhere except ``0`` at the
  queried node; the fixpoint assigns ``0`` exactly to the query's ancestors.

Rows are padded with self-indices; nodes with more than K neighbors are
split into virtual-node chains by the caller (see
``rust/src/runtime/remap.rs``), which preserves the fixpoint.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the grid walks row
blocks, so each grid step *owns* a disjoint output tile — no scatter races,
the TPU-legal analogue of GPU threadblock privatization. The parents block
(``BLOCK_ROWS × K`` int32) and the output tile live in VMEM; the labels
vector is the only shared operand (VMEM-resident up to the ~16 MiB budget,
i.e. N ≤ ~4M int32). ``interpret=True`` everywhere: the CPU PJRT client
cannot run Mosaic custom-calls, so the kernel lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 1024 rows × K=8 parents × 4 B = 32 KiB of indices per
# step plus the gathered tile — comfortably inside VMEM with double
# buffering headroom.
BLOCK_ROWS = 1024


def _relax_block_kernel(labels_ref, parents_ref, out_ref, *, block_rows: int):
    """One grid step: relax ``block_rows`` rows.

    labels_ref:  (N,)   full label vector (shared, read-only)
    parents_ref: (B, K) this block's padded parent indices
    out_ref:     (B,)   this block's new labels
    """
    labels = labels_ref[...]
    parents = parents_ref[...]
    gathered = labels[parents]  # (B, K) gather
    row_min = jnp.min(gathered, axis=1)
    i = pl.program_id(0)
    own = jax.lax.dynamic_slice(labels, (i * block_rows,), (block_rows,))
    out_ref[...] = jnp.minimum(own, row_min)


def relax_step(labels: jax.Array, parents: jax.Array) -> jax.Array:
    """One relaxation sweep: ``min(labels, min_k labels[parents[:, k]])``.

    labels: int32[N]; parents: int32[N, K]; N must be a multiple of
    BLOCK_ROWS (or smaller than it).
    """
    n, k = parents.shape
    assert labels.shape == (n,), (labels.shape, parents.shape)
    block = min(BLOCK_ROWS, n)
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    kernel = functools.partial(_relax_block_kernel, block_rows=block)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),        # full labels
            pl.BlockSpec((block, k), lambda i: (i, 0)),  # row block
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(labels, parents)
