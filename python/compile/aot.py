"""AOT lowering: JAX → HLO *text* artifacts for the Rust/PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Artifacts (one per size bucket, N × K static):

* ``relax_fixpoint_n{N}_k{K}.hlo.txt`` — inputs ``labels0 i32[N]``,
  ``parents i32[N,K]``; output ``(labels i32[N],)``. Used for both WCC
  (labels0 = iota) and ancestor closures (labels0 = indicator), see
  model.py.
* ``manifest.txt`` — one ``N K filename`` line per bucket; the Rust
  runtime picks the smallest bucket that fits and pads.

Usage: ``python -m compile.aot --out-dir ../artifacts [--buckets 4096,65536]``
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import relax_fixpoint

DEFAULT_BUCKETS = [1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]
K = 8


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, k: int) -> str:
    labels_spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    parents_spec = jax.ShapeDtypeStruct((n, k), jnp.int32)
    lowered = jax.jit(relax_fixpoint).lower(labels_spec, parents_spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in DEFAULT_BUCKETS),
        help="comma-separated N sizes (K is fixed at %d)" % K,
    )
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    buckets = [int(b) for b in args.buckets.split(",") if b]
    manifest_lines = []
    for n in buckets:
        text = lower_bucket(n, K)
        name = f"relax_fixpoint_n{n}_k{K}.hlo.txt"
        (out / name).write_text(text)
        manifest_lines.append(f"{n} {K} {name}")
        print(f"wrote {name} ({len(text)} chars)")
    (out / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest.txt ({len(buckets)} buckets)")


if __name__ == "__main__":
    main()
