"""L2 correctness: the while-loop fixpoint vs. union-find, and the
ancestor-closure encoding vs. a reachability oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    parents_matrix_from_edges,
    ref_relax_fixpoint,
    ref_wcc_labels,
)
from compile.model import reach_labels, relax_fixpoint, wcc_labels_from_parents


def random_edges(rng: np.random.Generator, n: int, m: int):
    return [tuple(rng.integers(0, n, size=2)) for _ in range(m)]


def pad_parents(mat: np.ndarray, n_pad: int) -> np.ndarray:
    """Pad the matrix to n_pad rows with self-parent rows."""
    n, k = mat.shape
    assert n_pad >= n
    out = np.tile(np.arange(n_pad, dtype=np.int32)[:, None], (1, k))
    out[:n] = mat
    return out


@pytest.mark.parametrize("n,m,k", [(16, 10, 4), (64, 80, 8), (128, 40, 3)])
def test_wcc_fixpoint_matches_union_find(n, m, k):
    rng = np.random.default_rng(n + m)
    edges = random_edges(rng, n, m)
    mat, n_total = parents_matrix_from_edges(n, edges, k)
    (labels,) = wcc_labels_from_parents(mat.astype(np.int32))
    labels = np.asarray(labels)[:n]
    np.testing.assert_array_equal(labels, ref_wcc_labels(n, edges))


def test_wcc_with_padding_rows():
    # Padded rows (self-parents) must stay isolated singletons.
    n = 8
    edges = [(0, 1), (2, 3)]
    mat, n_total = parents_matrix_from_edges(n, edges, 4)
    padded = pad_parents(mat, 32)
    (labels,) = wcc_labels_from_parents(padded)
    labels = np.asarray(labels)
    np.testing.assert_array_equal(labels[:n], ref_wcc_labels(n, edges))
    np.testing.assert_array_equal(labels[n_total:], np.arange(n_total, 32))


def test_high_degree_virtual_chaining():
    # A star with 50 leaves and K=4 forces virtual-node chains.
    n = 51
    edges = [(0, i) for i in range(1, n)]
    mat, n_total = parents_matrix_from_edges(n, edges, 4)
    assert n_total > n, "chaining must add virtual rows"
    (labels,) = wcc_labels_from_parents(mat)
    np.testing.assert_array_equal(np.asarray(labels)[:n], np.zeros(n, dtype=np.int32))


def test_reach_labels_simple_dag():
    # 0 → 2, 1 → 2, 2 → 3, 4 → 1; ancestors(3) = {0, 1, 2, 4}.
    # Pull matrix is over *children*: directed edge (src, dst) in src's row.
    n = 5
    edges = [(0, 2), (1, 2), (2, 3), (4, 1)]
    mat, _ = parents_matrix_from_edges(n, edges, 4, directed=True)
    (labels,) = reach_labels(mat, np.int32(3))
    reached = np.asarray(labels)[:n] == 0
    np.testing.assert_array_equal(reached, np.array([True] * 5))
    (labels2,) = reach_labels(mat, np.int32(2))
    reached2 = np.asarray(labels2)[:n] == 0
    np.testing.assert_array_equal(
        reached2, np.array([True, True, True, False, True])
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=48),
    m=st.integers(min_value=0, max_value=96),
    k=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_fixpoint_hypothesis(n, m, k, seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, n, m)
    mat, _ = parents_matrix_from_edges(n, edges, k)
    (labels,) = relax_fixpoint(np.arange(mat.shape[0], dtype=np.int32), mat)
    np.testing.assert_array_equal(np.asarray(labels)[:n], ref_wcc_labels(n, edges))


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=32),
    m=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_reach_matches_bfs_oracle(n, m, seed):
    rng = np.random.default_rng(seed)
    edges = [tuple(sorted(rng.integers(0, n, size=2))) for _ in range(m)]
    edges = [(a, b) for a, b in edges if a != b]  # DAG: low → high
    q = int(rng.integers(0, n))
    mat, _ = parents_matrix_from_edges(n, edges, 4, directed=True)
    (labels,) = reach_labels(mat, np.int32(q))
    got = set(np.nonzero(np.asarray(labels)[:n] == 0)[0])
    # BFS oracle backwards from q.
    want = {q}
    frontier = [q]
    while frontier:
        nxt = []
        for a, b in edges:
            if b in frontier and a not in want:
                want.add(a)
                nxt.append(a)
        frontier = nxt
    assert got == want


def test_ref_fixpoint_consistency():
    # The L2 fixpoint equals iterating the reference step.
    n, k = 32, 4
    rng = np.random.default_rng(3)
    edges = random_edges(rng, n, 40)
    mat, n_total = parents_matrix_from_edges(n, edges, k)
    labels0 = np.arange(n_total, dtype=np.int32)
    (got,) = relax_fixpoint(labels0, mat)
    np.testing.assert_array_equal(np.asarray(got), ref_relax_fixpoint(labels0, mat))
