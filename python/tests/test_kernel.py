"""L1 correctness: the Pallas relaxation kernel vs. the pure-numpy oracle.

Hypothesis sweeps shapes and adversarial index patterns; the kernel runs in
interpret mode (the same lowering the AOT artifacts embed)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.label_prop import BLOCK_ROWS, relax_step
from compile.kernels.ref import ref_relax_step


def random_case(rng: np.random.Generator, n: int, k: int):
    labels = rng.integers(0, n, size=n, dtype=np.int32)
    parents = rng.integers(0, n, size=(n, k), dtype=np.int32)
    return labels, parents


@pytest.mark.parametrize("n,k", [(8, 2), (64, 4), (256, 8), (1024, 8), (2048, 3)])
def test_relax_step_matches_ref(n, k):
    rng = np.random.default_rng(n * 31 + k)
    labels, parents = random_case(rng, n, k)
    got = np.asarray(relax_step(labels, parents))
    want = ref_relax_step(labels, parents)
    np.testing.assert_array_equal(got, want)


def test_relax_step_multiblock():
    # N spanning several grid steps exercises the block ownership logic.
    n, k = 4 * BLOCK_ROWS, 8
    rng = np.random.default_rng(7)
    labels, parents = random_case(rng, n, k)
    got = np.asarray(relax_step(labels, parents))
    np.testing.assert_array_equal(got, ref_relax_step(labels, parents))


def test_relax_step_identity_on_self_parents():
    # Rows padded entirely with self-indices must be a no-op.
    n, k = 128, 4
    labels = np.arange(n, dtype=np.int32)[::-1].copy()
    parents = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, k))
    got = np.asarray(relax_step(labels, parents))
    np.testing.assert_array_equal(got, labels)


def test_relax_step_monotone_non_increasing():
    n, k = 512, 8
    rng = np.random.default_rng(11)
    labels, parents = random_case(rng, n, k)
    got = np.asarray(relax_step(labels, parents))
    assert (got <= labels).all()


@settings(max_examples=30, deadline=None)
@given(
    log_n=st.integers(min_value=1, max_value=9),
    k=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_relax_step_hypothesis(log_n, k, seed):
    n = 1 << log_n  # powers of two, matching the bucket contract
    rng = np.random.default_rng(seed)
    labels, parents = random_case(rng, n, k)
    got = np.asarray(relax_step(labels, parents))
    want = ref_relax_step(labels, parents)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_relax_step_extreme_labels(seed):
    # int32 extremes must survive the min-reduction unharmed.
    n, k = 64, 4
    rng = np.random.default_rng(seed)
    labels = rng.choice(
        np.array([0, 1, 2**31 - 1, 12345], dtype=np.int32), size=n
    ).astype(np.int32)
    parents = rng.integers(0, n, size=(n, k), dtype=np.int32)
    got = np.asarray(relax_step(labels, parents))
    np.testing.assert_array_equal(got, ref_relax_step(labels, parents))
