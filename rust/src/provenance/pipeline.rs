//! End-to-end preprocessing pipeline: WCC → component tagging →
//! Algorithm 3 partitioning of large components → set-dependency
//! extraction. Produces everything the three query engines consume.

use crate::minispark::MiniSpark;
use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::partition::{Partitioner, PassStats};
use crate::provenance::setdeps::set_deps_driver;
use crate::provenance::wcc::{
    components_from_labels, wcc_driver, wcc_minispark, wcc_minispark_naive,
};
use crate::util::ids::{ComponentId, SetId};
use crate::util::timer::Timer;
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::SplitSet;
use rustc_hash::FxHashMap;

/// Which implementation computes the WCC labels.
pub enum WccImpl<'a> {
    /// Driver-side union-find (default, fastest on one box).
    Driver,
    /// Distributed frontier-based label propagation on minispark
    /// (paper-faithful phase; see `wcc.rs` module docs).
    MiniSpark { sc: &'a MiniSpark, partitions: usize },
    /// The pre-frontier full-reshuffle propagation — kept so benches and
    /// the CLI can compare against the frontier path.
    MiniSparkNaive { sc: &'a MiniSpark, partitions: usize },
    /// Custom labeller (the XLA/PJRT fixpoint from `runtime` plugs in here,
    /// keeping this module independent of artifact availability).
    Custom(&'a dyn Fn(&Trace) -> FxHashMap<u64, u64>),
}

/// A fully preprocessed trace: the inputs of RQ, CCProv and CSProv.
#[derive(Debug, Clone, Default)]
pub struct Preprocessed {
    /// node → component id (min node id in component).
    pub cc_of: FxHashMap<u64, u64>,
    /// node → connected-set id (min node id in set).
    pub cs_of: FxHashMap<u64, u64>,
    /// CCProv schema: triples tagged with their component.
    pub cc_triples: Vec<CcTriple>,
    /// CSProv schema: triples tagged with both endpoint set ids.
    pub cs_triples: Vec<CsTriple>,
    /// Distinct cross-set dependencies.
    pub set_deps: Vec<SetDep>,
    /// Table 9 rows: per large-component, per split pass statistics.
    pub pass_stats: Vec<PassStats>,
    /// Large components, descending by node count: (ccid, nodes, edges).
    pub large_components: Vec<(u64, usize, usize)>,
    /// Total number of weakly connected components.
    pub component_count: usize,
    /// Total number of weakly connected sets.
    pub set_count: usize,
    /// Phase timings (wcc / partition / tag / setdeps).
    pub timings: Vec<(String, std::time::Duration)>,
    /// Algorithm 3's θ this index was built with. Recorded so incremental
    /// delta application ([`crate::provenance::incremental`]) re-partitions
    /// growing components with the same cutoff; persisted by the store.
    pub theta: usize,
    /// The "big set" statistic bound the index was built with (Table 9);
    /// persisted alongside `theta` for the same reason.
    pub big_threshold: usize,
    /// Incremental epoch: 0 for a fresh [`preprocess`] run, bumped once per
    /// applied [`TripleBatch`](crate::provenance::incremental::TripleBatch).
    pub epoch: u64,
    /// Fingerprint of the workflow graph + splits this index was
    /// preprocessed under ([`crate::workflow::workflow_fingerprint`]);
    /// 0 = unrecorded (legacy v1/v2 store files). Ingestion re-partitions
    /// dirty components against a workflow, so `IncrementalIndex::new`
    /// refuses a recorded fingerprint that does not match its
    /// graph/splits — a mismatch would silently mis-partition.
    pub workflow_fingerprint: u64,
    /// Which shard of a component-space [`ShardPlan`] this index is
    /// (`shard_index < shard_count`); `shard_count == 0` means unsharded.
    /// Set by [`Preprocessed::split_by_plan`], persisted by the store.
    ///
    /// [`ShardPlan`]: crate::provenance::shard::ShardPlan
    pub shard_index: u64,
    /// Total shards in the plan this index was split under (0 = unsharded).
    pub shard_count: u64,
}

impl Preprocessed {
    /// Partition the index into per-shard indexes under a component-space
    /// [`ShardAssignment`]: every per-node map entry, tagged triple row,
    /// set dependency and large-component record follows its component's
    /// shard. Components are independent by construction (no triple or set
    /// dependency crosses them), so each shard is a complete, self-
    /// contained index over its components — per-shard `component_count` /
    /// `set_count` are recomputed, θ / big-set bound / epoch / workflow
    /// fingerprint carry over, and `shard_index`/`shard_count` record the
    /// position in the plan.
    ///
    /// Triple rows are emitted in index order, so each shard stays
    /// row-parallel with the [`Trace::split_by_plan`] output for the same
    /// assignment.
    ///
    /// [`ShardAssignment`]: crate::provenance::shard::ShardAssignment
    /// [`Trace::split_by_plan`]: crate::provenance::model::Trace::split_by_plan
    pub fn split_by_plan(
        &self,
        asg: &crate::provenance::shard::ShardAssignment,
    ) -> anyhow::Result<Vec<Preprocessed>> {
        let n = asg.shards();
        let mut out: Vec<Preprocessed> = (0..n)
            .map(|i| Preprocessed {
                theta: self.theta,
                big_threshold: self.big_threshold,
                epoch: self.epoch,
                workflow_fingerprint: self.workflow_fingerprint,
                shard_index: i as u64,
                shard_count: n as u64,
                ..Default::default()
            })
            .collect();
        let shard_of = |label: u64| -> anyhow::Result<usize> {
            asg.shard_of_label(label).ok_or_else(|| {
                anyhow::anyhow!("shard assignment does not cover component {label}")
            })
        };
        for (&node, &label) in &self.cc_of {
            out[shard_of(label)?].cc_of.insert(node, label);
        }
        for (&node, &sid) in &self.cs_of {
            let Some(&label) = self.cc_of.get(&node) else {
                anyhow::bail!("node {node} has a set id but no component label");
            };
            out[shard_of(label)?].cs_of.insert(node, sid);
        }
        anyhow::ensure!(
            self.cc_triples.len() == self.cs_triples.len(),
            "cc/cs triple arrays misaligned ({} vs {})",
            self.cc_triples.len(),
            self.cs_triples.len(),
        );
        for (cc_row, cs_row) in self.cc_triples.iter().zip(&self.cs_triples) {
            let s = shard_of(cc_row.ccid.0)?;
            out[s].cc_triples.push(*cc_row);
            out[s].cs_triples.push(*cs_row);
        }
        for d in &self.set_deps {
            // A set id is a member node of its component; both endpoints of
            // a dependency share one component (a triple witnesses it).
            let Some(&label) = self.cc_of.get(&d.src_csid.0) else {
                anyhow::bail!("set dependency references unknown set {}", d.src_csid.0);
            };
            out[shard_of(label)?].set_deps.push(*d);
        }
        for &(cc, nodes, edges) in &self.large_components {
            out[shard_of(cc)?].large_components.push((cc, nodes, edges));
        }
        for p in &mut out {
            let comps: rustc_hash::FxHashSet<u64> = p.cc_of.values().copied().collect();
            p.component_count = comps.len();
            let sets: rustc_hash::FxHashSet<u64> = p.cs_of.values().copied().collect();
            p.set_count = sets.len();
        }
        Ok(out)
    }
}

/// Run the full preprocessing pipeline.
///
/// * `theta` — Algorithm 3's θ **and** the large-component cutoff: any
///   component with ≥ θ nodes gets partitioned (smaller ones are managed
///   as single sets, per §2.3).
/// * `big_threshold` — the "≥ 1000 nodes" statistic bound of Table 9
///   (pass a scaled value when the trace is scaled down).
pub fn preprocess(
    trace: &Trace,
    graph: &DependencyGraph,
    splits: &SplitSet,
    theta: usize,
    big_threshold: usize,
    wcc: WccImpl<'_>,
) -> Preprocessed {
    let mut timer = Timer::new();
    let mut out = Preprocessed {
        theta,
        big_threshold,
        workflow_fingerprint: crate::workflow::workflow_fingerprint(graph, splits),
        ..Default::default()
    };

    // ---- Phase 1: weakly connected components ---------------------------
    let labels = match wcc {
        WccImpl::Driver => wcc_driver(trace),
        WccImpl::MiniSpark { sc, partitions } => wcc_minispark(sc, trace, partitions),
        WccImpl::MiniSparkNaive { sc, partitions } => {
            wcc_minispark_naive(sc, trace, partitions).0
        }
        WccImpl::Custom(f) => f(trace),
    };
    timer.lap("wcc");

    // Component inventory.
    let comps = components_from_labels(&labels);
    out.component_count = comps.len();
    let mut edge_count: FxHashMap<u64, usize> = FxHashMap::default();
    for t in &trace.triples {
        *edge_count.entry(labels[&t.src.raw()]).or_default() += 1;
    }
    let mut large: Vec<(u64, usize, usize)> = comps
        .iter()
        .filter(|(_, nodes)| nodes.len() >= theta)
        .map(|(&cc, nodes)| (cc, nodes.len(), edge_count.get(&cc).copied().unwrap_or(0)))
        .collect();
    large.sort_unstable_by(|a, b| b.1.cmp(&a.1));
    out.large_components = large;

    // ---- Phase 2: partition large components (Algorithm 3) --------------
    let partitioner = Partitioner { graph, splits, theta, big_threshold };
    // Group triples by component for the large ones.
    let large_ids: FxHashMap<u64, usize> = out
        .large_components
        .iter()
        .enumerate()
        .map(|(i, &(cc, _, _))| (cc, i))
        .collect();
    let mut large_triples: Vec<Vec<ProvTriple>> =
        vec![Vec::new(); out.large_components.len()];
    for t in &trace.triples {
        if let Some(&i) = large_ids.get(&labels[&t.src.raw()]) {
            large_triples[i].push(*t);
        }
    }
    let mut cs_of: FxHashMap<u64, u64> =
        FxHashMap::with_capacity_and_hasher(labels.len(), Default::default());
    for (i, triples) in large_triples.iter().enumerate() {
        let label = format!("LC{}", i + 1);
        let (sets, stats) = partitioner.partition_component(triples, &label);
        out.pass_stats.extend(stats);
        for set in sets {
            let sid = *set.iter().min().expect("non-empty set");
            for n in set {
                cs_of.insert(n, sid);
            }
        }
    }
    // Small components: one set each (its component id). For large
    // components this `or_insert` also backfills any node whose entity no
    // split covers (Algorithm 3 only assigns covered nodes).
    for (&node, &cc) in &labels {
        cs_of.entry(node).or_insert(cc);
    }
    // set_count = distinct set ids — the definition incremental maintenance
    // reconstructs and maintains, so the two always agree (including the
    // backfill case above, where a fallback group is a set of its own).
    let distinct_sets: rustc_hash::FxHashSet<u64> = cs_of.values().copied().collect();
    out.set_count = distinct_sets.len();
    timer.lap("partition");

    // ---- Phase 3: tag triples --------------------------------------------
    out.cc_triples = trace
        .triples
        .iter()
        .map(|&t| CcTriple { triple: t, ccid: ComponentId(labels[&t.dst.raw()]) })
        .collect();
    out.cs_triples = trace
        .triples
        .iter()
        .map(|&t| CsTriple {
            triple: t,
            src_csid: SetId(cs_of[&t.src.raw()]),
            dst_csid: SetId(cs_of[&t.dst.raw()]),
        })
        .collect();
    timer.lap("tag");

    // ---- Phase 4: set dependencies ----------------------------------------
    out.set_deps = set_deps_driver(&out.cs_triples);
    timer.lap("setdeps");

    out.cc_of = labels;
    out.cs_of = cs_of;
    out.timings = timer.laps().to_vec();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn tiny() -> (Trace, DependencyGraph, SplitSet) {
        generate(&GeneratorConfig { scale_divisor: 1000, ..Default::default() })
    }

    #[test]
    fn preprocess_covers_every_node_and_triple() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 500, 100, WccImpl::Driver);
        assert_eq!(pre.cc_triples.len(), trace.len());
        assert_eq!(pre.cs_triples.len(), trace.len());
        for t in &trace.triples {
            assert!(pre.cc_of.contains_key(&t.src.raw()));
            assert!(pre.cs_of.contains_key(&t.dst.raw()));
        }
    }

    #[test]
    fn preprocess_records_epoch_parameters() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 500, 100, WccImpl::Driver);
        assert_eq!(pre.theta, 500);
        assert_eq!(pre.big_threshold, 100);
        assert_eq!(pre.epoch, 0);
        assert_eq!(
            pre.workflow_fingerprint,
            crate::workflow::workflow_fingerprint(&g, &splits),
            "fingerprint must be recorded and deterministic"
        );
        assert_ne!(pre.workflow_fingerprint, 0);
        assert_eq!(pre.shard_count, 0, "a fresh preprocess is unsharded");
    }

    #[test]
    fn components_share_ccid_across_edges() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 500, 100, WccImpl::Driver);
        for t in &trace.triples {
            assert_eq!(pre.cc_of[&t.src.raw()], pre.cc_of[&t.dst.raw()]);
        }
    }

    #[test]
    fn sets_nest_inside_components() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 500, 100, WccImpl::Driver);
        // All nodes of one set belong to one component.
        let mut set_cc: FxHashMap<u64, u64> = FxHashMap::default();
        for (&node, &sid) in &pre.cs_of {
            let cc = pre.cc_of[&node];
            if let Some(&prev) = set_cc.get(&sid) {
                assert_eq!(prev, cc, "set {sid} spans components");
            } else {
                set_cc.insert(sid, cc);
            }
        }
        assert!(pre.set_count >= pre.component_count);
    }

    #[test]
    fn small_components_are_single_sets() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 500, 100, WccImpl::Driver);
        let large: std::collections::HashSet<u64> =
            pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
        for (&node, &sid) in &pre.cs_of {
            let cc = pre.cc_of[&node];
            if !large.contains(&cc) {
                assert_eq!(sid, cc, "small component not kept as one set");
            }
        }
    }

    #[test]
    fn set_deps_reference_real_sets() {
        let (trace, g, splits) = tiny();
        let pre = preprocess(&trace, &g, &splits, 300, 100, WccImpl::Driver);
        let sets: std::collections::HashSet<u64> = pre.cs_of.values().copied().collect();
        assert!(!pre.set_deps.is_empty(), "scaled trace should have cross-set deps");
        for d in &pre.set_deps {
            assert!(sets.contains(&d.src_csid.0));
            assert!(sets.contains(&d.dst_csid.0));
            assert_ne!(d.src_csid, d.dst_csid);
        }
    }

    #[test]
    fn finds_three_large_components() {
        let (trace, g, splits) = tiny();
        // θ scaled: divisor 1000 → LCs have ≥ ~300 nodes.
        let pre = preprocess(&trace, &g, &splits, 300, 100, WccImpl::Driver);
        assert!(
            pre.large_components.len() >= 3,
            "large components: {:?}",
            pre.large_components
        );
        assert!(pre.pass_stats.iter().any(|p| p.component == "LC1"));
    }
}
