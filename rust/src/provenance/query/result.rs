//! Query results: the lineage of an attribute-value.

use crate::provenance::model::ProvTriple;
use rustc_hash::FxHashSet;

/// The full lineage of a queried attribute-value: every ancestor and every
/// derivation step (triple) on a path into the queried value.
///
/// Canonical form — `triples` and `ancestors` are sorted and deduplicated —
/// so lineages from different engines compare with `==`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Lineage {
    /// The queried attribute-value (raw id).
    pub query: u64,
    /// All triples `⟨src, dst, op⟩` with `dst ∈ {query} ∪ ancestors`.
    pub triples: Vec<ProvTriple>,
    /// Distinct ancestors (excludes the queried value itself).
    pub ancestors: Vec<u64>,
}

impl Lineage {
    /// Empty lineage (the queried value is an input / unknown).
    pub fn empty(query: u64) -> Self {
        Self { query, triples: Vec::new(), ancestors: Vec::new() }
    }

    /// Build the canonical lineage from an (unordered, possibly duplicated)
    /// pile of lineage triples.
    pub fn from_triples(query: u64, mut triples: Vec<ProvTriple>) -> Self {
        triples.sort_unstable();
        triples.dedup();
        let mut ancestors: FxHashSet<u64> = FxHashSet::default();
        for t in &triples {
            ancestors.insert(t.src.raw());
            if t.dst.raw() != query {
                ancestors.insert(t.dst.raw());
            }
        }
        ancestors.remove(&query);
        let mut ancestors: Vec<u64> = ancestors.into_iter().collect();
        ancestors.sort_unstable();
        Self { query, triples, ancestors }
    }

    /// Number of distinct transformations involved.
    pub fn transformation_count(&self) -> usize {
        let ops: FxHashSet<u32> = self.triples.iter().map(|t| t.op.0).collect();
        ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.triples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn t(s: u64, d: u64, op: u32) -> ProvTriple {
        ProvTriple::new(
            AttrValueId::new(EntityId(0), s),
            AttrValueId::new(EntityId(0), d),
            OpId(op),
        )
    }

    #[test]
    fn canonicalizes() {
        let q = AttrValueId::new(EntityId(0), 9).raw();
        let a = Lineage::from_triples(q, vec![t(2, 9, 1), t(1, 2, 0), t(2, 9, 1)]);
        let b = Lineage::from_triples(q, vec![t(1, 2, 0), t(2, 9, 1)]);
        assert_eq!(a, b);
        assert_eq!(a.ancestors.len(), 2);
        assert_eq!(a.transformation_count(), 2);
    }

    #[test]
    fn empty_is_empty() {
        let l = Lineage::empty(5);
        assert!(l.is_empty());
        assert_eq!(l.transformation_count(), 0);
    }
}
