//! CSProv — Algorithm 2.
//!
//! Preprocessing partitions large components into weakly connected sets
//! (Algorithm 3) and materializes the set-dependency relation. A query:
//!
//! 1. resolves the queried item's connected set (`Find-Connected-Set`) by a
//!    single-partition lookup on a `(node → csid)` index,
//! 2. computes the **set-lineage** `S` — all sets contributing to the
//!    derivation of the item's set — by recursive querying over the
//!    (tiny) set-dependency dataset, hash-partitioned on `dst_csid`,
//! 3. assembles `cs_provRDD`: triples whose *derived* item lies in a set of
//!    `S`, via a partition-pruned lookup on the `dst_csid`-partitioned
//!    triple dataset — at most `|S|` partitions scanned,
//! 4. recurses over that minimal volume exactly like CCProv (driver-side
//!    when < τ).
//!
//! When the queried item lies in a small component, its component *is* its
//! set, the set-lineage is empty, and CSProv reduces to CCProv (§2.3).

use super::driver_rq::{bounded_closure, AncestorClosure, NativeClosure};
use super::engine::{ExecPath, ProvenanceEngine, QueryRequest, QueryResponse, QueryStats};
use super::result::Lineage;
use super::rq::{rq_bfs, BfsStats};
use crate::minispark::{Dataset, KeyTag, MiniSpark, ScanCost};
use crate::provenance::model::{CsTriple, ProvTriple, SetDep};
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// An incremental-preprocessing delta in the shape CSProv's three datasets
/// absorb it (assembled by `EngineSet::absorb` from an
/// [`AppliedDelta`](crate::provenance::incremental::AppliedDelta)).
///
/// Retagged triples may change their `dst_csid` — the partitioning key of
/// the triple dataset — so absorption is *drop old copies + re-route new
/// copies*, not an in-place patch: [`retagged`](Self::retagged) identifies
/// the rows to drop inside the partitions owned by
/// [`old_keys`](Self::old_keys), and [`rerouted`](Self::rerouted) carries
/// their new versions (plus nothing else — brand-new rows arrive via
/// [`appended`](Self::appended)).
pub struct CsDelta<'a> {
    /// Pre-existing triples whose set tags changed (old row → new row).
    pub retagged: &'a FxHashMap<ProvTriple, CsTriple>,
    /// Distinct *old* `dst_csid` keys of the retagged rows (where their old
    /// copies live).
    pub old_keys: &'a [u64],
    /// New versions of the retagged rows, one per old row occurrence.
    pub rerouted: &'a [CsTriple],
    /// Rows appended by the batch (already tagged).
    pub appended: &'a [CsTriple],
    /// Pre-existing nodes whose connected-set id changed (`node` is the
    /// index key and never changes — patched in place).
    pub node_patch: &'a FxHashMap<u64, u64>,
    /// Nodes first seen in the batch: `(node, csid)`.
    pub new_nodes: &'a [(u64, u64)],
    /// Set dependencies to drop (their component was recomputed)…
    pub removed_deps: &'a FxHashSet<SetDep>,
    /// …and the distinct `dst_csid` keys owning them.
    pub removed_dep_keys: &'a [u64],
    /// Recomputed set dependencies for the dirty components.
    pub added_deps: &'a [SetDep],
}

/// One memoized `cs_provRDD` assemble. The set-lineage — and therefore
/// the pruned fetch — is a pure function of the resolved set id, so hits
/// replay the cold run's [`ScanCost`] and per-query attribution stays
/// deterministic whether the hot set was shared or not. Only the
/// *assemble* is memoized: the cluster branch's `by_dst` re-partition
/// still runs per query, keeping the engine-wide `rows_shuffled` ledger
/// faithful.
struct AssembledCs {
    cs_prov: Dataset<CsTriple>,
    volume: usize,
    cost: ScanCost,
}

/// Algorithm 2 engine.
pub struct CsProvEngine {
    /// Triples, hash-partitioned on `dst_csid` (the paper's layout).
    prov_by_set: Dataset<CsTriple>,
    /// `(node, csid)` index, hash-partitioned on node — how
    /// `Find-Connected-Set` resolves a queried item in one partition scan.
    /// Built once at construction and reused by every query.
    node_set: Dataset<(u64, u64)>,
    /// Set dependencies, hash-partitioned on `dst_csid` (child set).
    set_deps: Dataset<SetDep>,
    num_partitions: usize,
    tau: usize,
    closure: Arc<dyn AncestorClosure>,
    /// Hot-set memo: a small epoch-keyed LRU of assembles (see
    /// [`AssembledCs`] and [`AssembleMemo`](super::AssembleMemo)).
    assembled: Mutex<super::AssembleMemo<u64, AssembledCs>>,
}

impl CsProvEngine {
    /// Build from preprocessed set-tagged data. Triples and set
    /// dependencies are borrowed slices partitioned in one pass (no copy of
    /// the full `Vec`s); `node_set` is the derived `(node, csid)` index,
    /// produced once by the caller (see `EngineSet::build`).
    pub fn new(
        sc: &MiniSpark,
        cs_triples: &[CsTriple],
        node_set: Vec<(u64, u64)>,
        set_deps: &[SetDep],
        num_partitions: usize,
        tau: usize,
    ) -> Self {
        let np = num_partitions;
        let prov_by_set = Dataset::hash_partitioned_from_slice(
            sc,
            cs_triples,
            np,
            super::KEY_DST_CSID,
            |t: &CsTriple| t.dst_csid.0,
        );
        let node_set = Dataset::hash_partitioned_from_slice(
            sc,
            &node_set,
            np,
            KeyTag::PAIR_KEY,
            |r: &(u64, u64)| r.0,
        );
        let set_deps = Dataset::hash_partitioned_from_slice(
            sc,
            set_deps,
            np,
            super::KEY_DST_CSID,
            |d: &SetDep| d.dst_csid.0,
        );
        Self {
            prov_by_set,
            node_set,
            set_deps,
            num_partitions: np,
            tau,
            closure: Arc::new(NativeClosure),
            assembled: Mutex::new(super::AssembleMemo::new(super::ASSEMBLE_MEMO_WAYS)),
        }
    }

    /// Wrap three already-partitioned datasets — e.g. demand-paged triple
    /// partitions of a segmented preprocessed store plus freshly spilled
    /// node / set-dependency indexes — without re-shuffling or copying
    /// them. `num_partitions` must match the datasets' partition count.
    ///
    /// Panics if the triple dataset carries no hash partitioning.
    pub fn from_datasets(
        prov_by_set: Dataset<CsTriple>,
        node_set: Dataset<(u64, u64)>,
        set_deps: Dataset<SetDep>,
        num_partitions: usize,
        tau: usize,
    ) -> Self {
        assert!(
            prov_by_set.partitioning().is_some(),
            "CsProvEngine::from_datasets requires hash-partitioned datasets"
        );
        Self {
            prov_by_set,
            node_set,
            set_deps,
            num_partitions,
            tau,
            closure: Arc::new(NativeClosure),
            assembled: Mutex::new(super::AssembleMemo::new(super::ASSEMBLE_MEMO_WAYS)),
        }
    }

    /// Swap the driver-side closure implementation (native / XLA).
    pub fn with_closure(mut self, closure: Arc<dyn AncestorClosure>) -> Self {
        self.closure = closure;
        self
    }

    /// Delta ingest: absorb an incremental-preprocessing delta across all
    /// three datasets without rebuilding them — retagged triples are
    /// dropped from their old `dst_csid` partitions and re-routed under
    /// their new key, appended rows are routed in place, the `(node, csid)`
    /// index is patched for changed nodes and extended for new ones, and
    /// the set-dependency dataset absorbs the dirty components' diff.
    pub fn with_delta(&self, d: &CsDelta<'_>) -> Self {
        let mut prov_by_set = if d.old_keys.is_empty() {
            self.prov_by_set.clone()
        } else {
            self.prov_by_set.patch_partitions(d.old_keys, |t| {
                if d.retagged.contains_key(&t.triple) {
                    None
                } else {
                    Some(*t)
                }
            })
        };
        prov_by_set = prov_by_set.append_partitioned(d.rerouted).append_partitioned(d.appended);

        let mut node_set = if d.node_patch.is_empty() {
            self.node_set.clone()
        } else {
            let keys: Vec<u64> = d.node_patch.keys().copied().collect();
            self.node_set.patch_partitions(&keys, |&(n, c)| {
                Some((n, d.node_patch.get(&n).copied().unwrap_or(c)))
            })
        };
        node_set = node_set.append_partitioned(d.new_nodes);

        let mut set_deps = if d.removed_dep_keys.is_empty() {
            self.set_deps.clone()
        } else {
            self.set_deps.patch_partitions(d.removed_dep_keys, |dep| {
                if d.removed_deps.contains(dep) {
                    None
                } else {
                    Some(*dep)
                }
            })
        };
        set_deps = set_deps.append_partitioned(d.added_deps);

        Self {
            prov_by_set,
            node_set,
            set_deps,
            num_partitions: self.num_partitions,
            tau: self.tau,
            closure: Arc::clone(&self.closure),
            // Any memoized set may have been retagged: the successor memo
            // is one epoch later, so nothing stale can replay.
            assembled: Mutex::new(self.assembled.lock().expect("cs memo lock").successor()),
        }
    }

    /// Spill all three datasets to segment files ([`Dataset::spilled`]);
    /// a no-op clone without a memory budget. The node index and set
    /// dependencies spill too: they are small, but the budget's promise is
    /// that *everything* pages, so a pathological budget still works.
    pub fn spilled(&self) -> anyhow::Result<Self> {
        Ok(Self {
            prov_by_set: self.prov_by_set.spilled("cs-prov")?,
            node_set: self.node_set.spilled("cs-nodeset")?,
            set_deps: self.set_deps.spilled("cs-setdeps")?,
            num_partitions: self.num_partitions,
            tau: self.tau,
            closure: Arc::clone(&self.closure),
            // A memoized set would pin pre-spill partitions resident: the
            // successor memo starts empty one epoch later.
            assembled: Mutex::new(self.assembled.lock().expect("cs memo lock").successor()),
        })
    }

    /// Assemble `cs_provRDD` for set-lineage `s` (whose resolved root is
    /// `cs`): a partition-pruned fetch, memoized per set in a small LRU.
    /// `s` is a pure function of `cs`, so the memo key is just `cs`, and
    /// hits replay the cold fetch's deterministic [`ScanCost`].
    fn assemble(&self, cs: u64, s: &[u64]) -> (Dataset<CsTriple>, usize, ScanCost) {
        if let Some(a) = self.assembled.lock().expect("cs memo lock").get(cs) {
            return (a.cs_prov.clone(), a.volume, a.cost);
        }
        let (cs_prov, cost) = self.prov_by_set.prune_lookup_counted(s);
        let volume = cs_prov.count();
        self.assembled
            .lock()
            .expect("cs memo lock")
            .put(cs, AssembledCs { cs_prov: cs_prov.clone(), volume, cost });
        (cs_prov, volume, cost)
    }

    /// The set-lineage of set `cs`: every set contributing to its
    /// derivation, directly or indirectly (RQ over the set-dependency
    /// dataset — lightweight because both the dataset and the lineage are
    /// small; §2.3).
    pub fn set_lineage(&self, cs: u64) -> Vec<u64> {
        self.set_lineage_counted(cs).0
    }

    /// [`set_lineage`](Self::set_lineage) plus the walk's scan cost.
    fn set_lineage_counted(&self, cs: u64) -> (Vec<u64>, BfsStats) {
        let mut stats = BfsStats::default();
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        seen.insert(cs);
        let mut frontier = vec![cs];
        let mut out = Vec::new();
        // Frontier-driven readahead over the set-dependency dataset: the
        // batch pins its pages until the round that consumes them.
        let mut readahead: Option<crate::storage::PrefetchBatch> = None;
        while !frontier.is_empty() {
            let (deps, cost) = self.set_deps.multi_lookup_counted(&frontier);
            // This round consumed its readahead; release the pins.
            drop(readahead.take());
            stats.rounds += 1;
            stats.partitions += cost.partitions;
            stats.rows += cost.rows;
            stats.cache_hits += cost.cache_hits;
            stats.cache_misses += cost.cache_misses;
            let mut next = Vec::new();
            for d in deps {
                if seen.insert(d.src_csid.0) {
                    next.push(d.src_csid.0);
                    out.push(d.src_csid.0);
                }
            }
            // The next frontier is known a full round early: warm its
            // partitions in the background while the driver bookkeeping
            // (and the next job's launch overhead) runs.
            readahead = self.set_deps.prefetch(&next);
            frontier = next;
        }
        (out, stats)
    }

    /// Algorithm 2: lineage of `q` (see [`ProvenanceEngine::query`]).
    pub fn query(&self, q: u64) -> Lineage {
        self.execute(&QueryRequest::new(q)).lineage
    }

    /// Size of the minimal volume CSProv would recurse over for `q`
    /// (triples in the set-lineage) — the paper's Discussion metric
    /// ("CSProv needs to recursively query only 4177 provenance triples
    /// while CCProv needs to query 2.7M").
    pub fn lineage_volume(&self, q: u64) -> usize {
        let rows = self.node_set.lookup(q);
        let Some(&(_, cs)) = rows.first() else { return 0 };
        let mut s = self.set_lineage(cs);
        s.push(cs);
        self.prov_by_set.prune_lookup(&s).count()
    }
}

impl ProvenanceEngine for CsProvEngine {
    fn name(&self) -> &'static str {
        "csprov"
    }

    fn execute(&self, req: &QueryRequest) -> QueryResponse {
        let q = req.item;
        let tau = req.tau_override.unwrap_or(self.tau);
        let mut stats = QueryStats::new("csprov");

        // Find-Connected-Set: one partition scan on the node index, then
        // the set-lineage walk over the set-dependency dataset. The
        // deadline clock starts here: resolve/assemble time counts against
        // the budget, but only the recursion phase is cut (the set-lineage
        // walk and assembly are small by construction — §2.3).
        let t0 = Instant::now();
        let deadline = req.deadline.map(|d| t0 + d);
        let (rows, cost) = self.node_set.lookup_counted(q);
        stats.partitions_scanned += cost.partitions;
        stats.rows_examined += cost.rows;
        stats.cache_hits += cost.cache_hits;
        stats.cache_misses += cost.cache_misses;
        let Some(&(_, cs)) = rows.first() else {
            stats.resolve = t0.elapsed();
            return QueryResponse { lineage: Lineage::empty(q), stats };
        };
        let (mut s, walk) = self.set_lineage_counted(cs);
        stats.partitions_scanned += walk.partitions;
        stats.rows_examined += walk.rows;
        stats.cache_hits += walk.cache_hits;
        stats.cache_misses += walk.cache_misses;
        s.push(cs);
        stats.resolve = t0.elapsed();

        // cs_provRDD: triples whose derived item is in a set of S.
        // Partition-pruned (at most |S| distinct partitions), memoized per
        // set with the cold cost replayed on hits.
        let t1 = Instant::now();
        let (cs_prov, volume, cost) = self.assemble(cs, &s);
        stats.partitions_scanned += cost.partitions;
        stats.rows_examined += cost.rows;
        stats.cache_hits += cost.cache_hits;
        stats.cache_misses += cost.cache_misses;
        stats.assemble = t1.elapsed();

        let t2 = Instant::now();
        let lineage = if volume >= tau {
            // RQ on the cluster. The pruned dataset is partitioned by
            // dst_csid; recursive lookups key on dst, so repartition first
            // (a shuffle of only the minimal volume — the tags differ, so
            // the engine correctly refuses to elide it).
            stats.path = ExecPath::Cluster;
            stats.rows_shuffled += volume as u64;
            let by_dst = cs_prov.hash_partition_by_tagged(
                self.num_partitions,
                super::KEY_TRIPLE_DST,
                |t: &CsTriple| t.triple.dst.raw(),
            );
            let (lineage, bfs) =
                rq_bfs(&by_dst, |t| t.triple, q, req.max_depth, req.max_triples, deadline);
            stats.partitions_scanned += bfs.partitions;
            stats.rows_examined += bfs.rows;
            stats.cache_hits += bfs.cache_hits;
            stats.cache_misses += bfs.cache_misses;
            stats.bfs_rounds = bfs.rounds;
            stats.truncated = bfs.truncated;
            stats.completeness = bfs.completeness();
            lineage
        } else {
            stats.path = ExecPath::Driver;
            let triples: Vec<ProvTriple> =
                cs_prov.collect().into_iter().map(|t| t.triple).collect();
            stats.rows_collected = triples.len() as u64;
            if req.max_depth.is_none() && req.max_triples.is_none() && deadline.is_none() {
                self.closure.closure(&triples, q)
            } else {
                // Caps and deadlines require level-order expansion, which
                // the pluggable fixpoint closures can't provide (see
                // QueryRequest docs).
                let (lineage, bfs) =
                    bounded_closure(&triples, q, req.max_depth, req.max_triples, deadline);
                stats.bfs_rounds = bfs.rounds;
                stats.truncated = bfs.truncated;
                stats.completeness = bfs.completeness();
                lineage
            }
        };
        stats.recurse = t2.elapsed();
        QueryResponse { lineage, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::pipeline::{preprocess, Preprocessed, WccImpl};
    use crate::provenance::query::ccprov::CcProvEngine;
    use crate::provenance::query::rq::RqEngine;
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    fn build(pre: &Preprocessed, s: &MiniSpark, tau: usize) -> CsProvEngine {
        CsProvEngine::new(
            s,
            &pre.cs_triples,
            pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect(),
            &pre.set_deps,
            16,
            tau,
        )
    }

    #[test]
    fn csprov_matches_rq_and_ccprov() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        // Small θ so the large components really get partitioned.
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let s = sc();
        let rq = RqEngine::new(&s, &trace.triples, 16);
        let cc = CcProvEngine::new(&s, &pre.cc_triples, 16, 1000);
        let queries: Vec<u64> = trace
            .triples
            .iter()
            .step_by(trace.len() / 10 + 1)
            .map(|t| t.dst.raw())
            .collect();
        for tau in [0usize, usize::MAX] {
            let cs = build(&pre, &s, tau);
            for &q in &queries {
                let want = rq.query(q);
                assert_eq!(cs.query(q), want, "q={q} tau={tau}");
                assert_eq!(cc.query(q), want, "ccprov q={q}");
            }
        }
    }

    #[test]
    fn set_lineage_soundness() {
        // The union of triples with dst in the set-lineage must contain the
        // entire lineage of any item in the queried set.
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let s = sc();
        let cs_engine = build(&pre, &s, usize::MAX);
        let rq = RqEngine::new(&s, &trace.triples, 16);
        for t in trace.triples.iter().step_by(trace.len() / 6 + 1) {
            let q = t.dst.raw();
            let full = rq.query(q);
            let vol = cs_engine.lineage_volume(q);
            assert!(
                vol >= full.triples.len(),
                "set-lineage volume {vol} < lineage {}",
                full.triples.len()
            );
        }
    }

    #[test]
    fn small_component_reduces_to_ccprov() {
        // For an item in a small component the set-lineage must be empty
        // (its component is one set).
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let large: FxHashSet<u64> =
            pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
        // Find an item in a small component.
        let q = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| !large.contains(&pre.cc_of[n]))
            .expect("small-component item");
        let s = sc();
        let engine = build(&pre, &s, usize::MAX);
        let cs = pre.cs_of[&q];
        assert_eq!(cs, pre.cc_of[&q], "small component is a single set");
        assert!(engine.set_lineage(cs).is_empty());
    }

    #[test]
    fn lineage_volume_much_smaller_in_large_component() {
        // The CSProv minimal volume for a large-component item must be far
        // below the component size (the paper's 60K vs 2.7M argument).
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 1000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 300, 100, WccImpl::Driver);
        let (lc1, _, lc1_edges) = pre.large_components[0];
        let s = sc();
        let engine = build(&pre, &s, usize::MAX);
        // Average volume over a few large-component items.
        let items: Vec<u64> = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .filter(|n| pre.cc_of[n] == lc1)
            .step_by(97)
            .take(8)
            .collect();
        assert!(!items.is_empty());
        let avg: usize =
            items.iter().map(|&q| engine.lineage_volume(q)).sum::<usize>() / items.len();
        assert!(
            avg * 2 < lc1_edges,
            "avg volume {avg} not ≪ component edges {lc1_edges}"
        );
    }

    #[test]
    fn memo_retains_multiple_hot_sets() {
        // Interleaving a second connected set must not evict the first:
        // the single-slot memo this LRU replaced would re-assemble A's
        // pruned fetch after B.
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let s = sc();
        let engine = build(&pre, &s, usize::MAX);
        let qa = trace.triples[trace.len() / 3].dst.raw();
        let qb = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| pre.cs_of[n] != pre.cs_of[&qa])
            .expect("an item in a second set");
        let a_cold = engine.execute(&QueryRequest::new(qa));
        let _ = engine.execute(&QueryRequest::new(qb));
        let before = s.metrics().snapshot();
        let a_warm = engine.execute(&QueryRequest::new(qa));
        let warm_jobs = s.metrics().snapshot().since(&before).jobs;
        assert_eq!(a_cold.lineage, a_warm.lineage);
        assert_eq!(a_cold.stats.rows_examined, a_warm.stats.rows_examined);
        // A fresh engine answering the same query shows what the cold
        // assemble costs in jobs; the warm replay must run strictly fewer.
        let fresh = build(&pre, &s, usize::MAX);
        let before = s.metrics().snapshot();
        let _ = fresh.execute(&QueryRequest::new(qa));
        let cold_jobs = s.metrics().snapshot().since(&before).jobs;
        assert!(warm_jobs < cold_jobs, "warm ran {warm_jobs} jobs, cold {cold_jobs}");
    }
}
