//! CSProv — Algorithm 2.
//!
//! Preprocessing partitions large components into weakly connected sets
//! (Algorithm 3) and materializes the set-dependency relation. A query:
//!
//! 1. resolves the queried item's connected set (`Find-Connected-Set`) by a
//!    single-partition lookup on a `(node → csid)` index,
//! 2. computes the **set-lineage** `S` — all sets contributing to the
//!    derivation of the item's set — by recursive querying over the
//!    (tiny) set-dependency dataset, hash-partitioned on `dst_csid`,
//! 3. assembles `cs_provRDD`: triples whose *derived* item lies in a set of
//!    `S`, via a partition-pruned lookup on the `dst_csid`-partitioned
//!    triple dataset — at most `|S|` partitions scanned,
//! 4. recurses over that minimal volume exactly like CCProv (driver-side
//!    when < τ).
//!
//! When the queried item lies in a small component, its component *is* its
//! set, the set-lineage is empty, and CSProv reduces to CCProv (§2.3).

use super::driver_rq::{AncestorClosure, NativeClosure};
use super::result::Lineage;
use super::rq::rq_on_spark_generic;
use crate::minispark::{Dataset, MiniSpark};
use crate::provenance::model::{CsTriple, ProvTriple, SetDep};
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Algorithm 2 engine.
pub struct CsProvEngine {
    /// Triples, hash-partitioned on `dst_csid` (the paper's layout).
    prov_by_set: Dataset<CsTriple>,
    /// `(node, csid)` index, hash-partitioned on node — how
    /// `Find-Connected-Set` resolves a queried item in one partition scan.
    node_set: Dataset<(u64, u64)>,
    /// Set dependencies, hash-partitioned on `dst_csid` (child set).
    set_deps: Dataset<SetDep>,
    num_partitions: usize,
    tau: usize,
    closure: Arc<dyn AncestorClosure>,
}

impl CsProvEngine {
    pub fn new(
        sc: &MiniSpark,
        cs_triples: Vec<CsTriple>,
        node_set: Vec<(u64, u64)>,
        set_deps: Vec<SetDep>,
        num_partitions: usize,
        tau: usize,
    ) -> Self {
        let np = num_partitions;
        let prov_by_set = Dataset::from_vec(sc, cs_triples, np)
            .hash_partition_by_tagged(np, super::KEY_DST_CSID, |t: &CsTriple| t.dst_csid.0)
            .cache();
        let node_set = Dataset::from_vec(sc, node_set, np).partition_by_key(np).cache();
        let set_deps = Dataset::from_vec(sc, set_deps, np)
            .hash_partition_by_tagged(np, super::KEY_DST_CSID, |d: &SetDep| d.dst_csid.0)
            .cache();
        Self { prov_by_set, node_set, set_deps, num_partitions: np, tau, closure: Arc::new(NativeClosure) }
    }

    /// Swap the driver-side closure implementation (native / XLA).
    pub fn with_closure(mut self, closure: Arc<dyn AncestorClosure>) -> Self {
        self.closure = closure;
        self
    }

    /// The set-lineage of set `cs`: every set contributing to its
    /// derivation, directly or indirectly (RQ over the set-dependency
    /// dataset — lightweight because both the dataset and the lineage are
    /// small; §2.3).
    pub fn set_lineage(&self, cs: u64) -> Vec<u64> {
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        seen.insert(cs);
        let mut frontier = vec![cs];
        let mut out = Vec::new();
        while !frontier.is_empty() {
            let deps = self.set_deps.multi_lookup(&frontier);
            let mut next = Vec::new();
            for d in deps {
                if seen.insert(d.src_csid.0) {
                    next.push(d.src_csid.0);
                    out.push(d.src_csid.0);
                }
            }
            frontier = next;
        }
        out
    }

    /// Algorithm 2: lineage of `q`.
    pub fn query(&self, q: u64) -> Lineage {
        // Find-Connected-Set: one partition scan on the node index.
        let rows = self.node_set.lookup(q);
        let Some(&(_, cs)) = rows.first() else {
            return Lineage::empty(q);
        };

        // S ← cs ∪ Find-Set-Lineage(setDepRDD, cs).
        let mut s = self.set_lineage(cs);
        s.push(cs);

        // cs_provRDD: triples whose derived item is in a set of S.
        // Partition-pruned: scans at most |S| distinct partitions.
        let cs_prov = self.prov_by_set.prune_lookup(&s);

        if cs_prov.count() >= self.tau {
            // RQ on the cluster. The pruned dataset is partitioned by
            // dst_csid; recursive lookups key on dst, so repartition first
            // (a shuffle of only the minimal volume — the tags differ, so
            // the engine correctly refuses to elide it).
            let by_dst = cs_prov.hash_partition_by_tagged(
                self.num_partitions,
                super::KEY_TRIPLE_DST,
                |t: &CsTriple| t.triple.dst.raw(),
            );
            rq_on_spark_generic(&by_dst, |t| t.triple, q)
        } else {
            let triples: Vec<ProvTriple> =
                cs_prov.collect().into_iter().map(|t| t.triple).collect();
            self.closure.closure(&triples, q)
        }
    }

    /// Size of the minimal volume CSProv would recurse over for `q`
    /// (triples in the set-lineage) — the paper's Discussion metric
    /// ("CSProv needs to recursively query only 4177 provenance triples
    /// while CCProv needs to query 2.7M").
    pub fn lineage_volume(&self, q: u64) -> usize {
        let rows = self.node_set.lookup(q);
        let Some(&(_, cs)) = rows.first() else { return 0 };
        let mut s = self.set_lineage(cs);
        s.push(cs);
        self.prov_by_set.prune_lookup(&s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::pipeline::{preprocess, Preprocessed, WccImpl};
    use crate::provenance::query::ccprov::CcProvEngine;
    use crate::provenance::query::rq::RqEngine;
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    fn build(pre: &Preprocessed, s: &MiniSpark, tau: usize) -> CsProvEngine {
        CsProvEngine::new(
            s,
            pre.cs_triples.clone(),
            pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect(),
            pre.set_deps.clone(),
            16,
            tau,
        )
    }

    #[test]
    fn csprov_matches_rq_and_ccprov() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        // Small θ so the large components really get partitioned.
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let s = sc();
        let rq = RqEngine::new(&s, &trace, 16);
        let cc = CcProvEngine::new(&s, pre.cc_triples.clone(), 16, 1000);
        let queries: Vec<u64> = trace
            .triples
            .iter()
            .step_by(trace.len() / 10 + 1)
            .map(|t| t.dst.raw())
            .collect();
        for tau in [0usize, usize::MAX] {
            let cs = build(&pre, &s, tau);
            for &q in &queries {
                let want = rq.query(q);
                assert_eq!(cs.query(q), want, "q={q} tau={tau}");
                assert_eq!(cc.query(q), want, "ccprov q={q}");
            }
        }
    }

    #[test]
    fn set_lineage_soundness() {
        // The union of triples with dst in the set-lineage must contain the
        // entire lineage of any item in the queried set.
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let s = sc();
        let cs_engine = build(&pre, &s, usize::MAX);
        let rq = RqEngine::new(&s, &trace, 16);
        for t in trace.triples.iter().step_by(trace.len() / 6 + 1) {
            let q = t.dst.raw();
            let full = rq.query(q);
            let vol = cs_engine.lineage_volume(q);
            assert!(
                vol >= full.triples.len(),
                "set-lineage volume {vol} < lineage {}",
                full.triples.len()
            );
        }
    }

    #[test]
    fn small_component_reduces_to_ccprov() {
        // For an item in a small component the set-lineage must be empty
        // (its component is one set).
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let large: FxHashSet<u64> =
            pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
        // Find an item in a small component.
        let q = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| !large.contains(&pre.cc_of[n]))
            .expect("small-component item");
        let s = sc();
        let engine = build(&pre, &s, usize::MAX);
        let cs = pre.cs_of[&q];
        assert_eq!(cs, pre.cc_of[&q], "small component is a single set");
        assert!(engine.set_lineage(cs).is_empty());
    }

    #[test]
    fn lineage_volume_much_smaller_in_large_component() {
        // The CSProv minimal volume for a large-component item must be far
        // below the component size (the paper's 60K vs 2.7M argument).
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 1000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 300, 100, WccImpl::Driver);
        let (lc1, _, lc1_edges) = pre.large_components[0];
        let s = sc();
        let engine = build(&pre, &s, usize::MAX);
        // Average volume over a few large-component items.
        let items: Vec<u64> = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .filter(|n| pre.cc_of[n] == lc1)
            .step_by(97)
            .take(8)
            .collect();
        assert!(!items.is_empty());
        let avg: usize =
            items.iter().map(|&q| engine.lineage_volume(q)).sum::<usize>() / items.len();
        assert!(
            avg * 2 < lc1_edges,
            "avg volume {avg} not ≪ component edges {lc1_edges}"
        );
    }
}
