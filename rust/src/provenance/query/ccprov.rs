//! CCProv — Algorithm 1.
//!
//! Preprocessing tags every triple with its weakly connected component id.
//! A query (1) resolves the queried item's component by a single-partition
//! lookup, (2) filters the component's triples (one full scan that
//! preserves the dst hash-partitioning), and (3) recursively queries only
//! that component — on the cluster when it holds ≥ τ triples, otherwise
//! collected to the driver (Spark job launch overhead dominates tiny jobs;
//! see §2.2 "Further Optimization").

use super::driver_rq::{AncestorClosure, NativeClosure};
use super::result::Lineage;
use super::rq::rq_on_spark_generic;
use crate::minispark::{Dataset, MiniSpark};
use crate::provenance::model::{CcTriple, ProvTriple};
use std::sync::Arc;

/// Algorithm 1 engine.
pub struct CcProvEngine {
    prov: Dataset<CcTriple>,
    tau: usize,
    closure: Arc<dyn AncestorClosure>,
}

impl CcProvEngine {
    /// Build from preprocessed component-tagged triples.
    pub fn new(
        sc: &MiniSpark,
        cc_triples: Vec<CcTriple>,
        num_partitions: usize,
        tau: usize,
    ) -> Self {
        let prov = Dataset::from_vec(sc, cc_triples, num_partitions)
            .hash_partition_by_tagged(num_partitions, super::KEY_TRIPLE_DST, |t: &CcTriple| {
                t.triple.dst.raw()
            })
            .cache();
        Self { prov, tau, closure: Arc::new(NativeClosure) }
    }

    /// Swap the driver-side closure implementation (native / XLA).
    pub fn with_closure(mut self, closure: Arc<dyn AncestorClosure>) -> Self {
        self.closure = closure;
        self
    }

    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Algorithm 1: lineage of `q`.
    pub fn query(&self, q: u64) -> Lineage {
        // Find-Connected-Component: one partition scan.
        let rows = self.prov.lookup(q);
        let Some(first) = rows.first() else {
            return Lineage::empty(q); // input value or unknown: no lineage
        };
        let ccid = first.ccid;

        // Find-Prov-Triples-In-Component: filter, partitioning preserved.
        let c_prov = self.prov.filter(move |t| t.ccid == ccid);

        if c_prov.count() >= self.tau {
            // RQ on the cluster over the component's triples.
            rq_on_spark_generic(&c_prov, |t| t.triple, q)
        } else {
            // Collect to the driver and recurse locally.
            let triples: Vec<ProvTriple> =
                c_prov.collect().into_iter().map(|t| t.triple).collect();
            self.closure.closure(&triples, q)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::model::Trace;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::provenance::query::rq::RqEngine;
    use crate::util::ids::{AttrValueId, EntityId, OpId};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn ccprov_matches_rq_both_tau_branches() {
        let (trace, g, splits) = generate(&GeneratorConfig {
            scale_divisor: 2000,
            ..Default::default()
        });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let rq = RqEngine::new(&s, &trace, 16);
        // Pick a handful of derived items.
        let queries: Vec<u64> = trace
            .triples
            .iter()
            .step_by(trace.len() / 8 + 1)
            .map(|t| t.dst.raw())
            .collect();
        for tau in [0usize, usize::MAX] {
            let cc = CcProvEngine::new(&s, pre.cc_triples.clone(), 16, tau);
            for &q in &queries {
                assert_eq!(cc.query(q), rq.query(q), "q={q} tau={tau}");
            }
        }
    }

    #[test]
    fn unknown_item_is_empty() {
        let trace = Trace::new(vec![ProvTriple::new(
            AttrValueId::new(EntityId(0), 1),
            AttrValueId::new(EntityId(1), 1),
            OpId(0),
        )]);
        let (g, splits) = crate::workflow::curation::text_curation_workflow();
        let pre = preprocess(&trace, &g, &splits, 100, 100, WccImpl::Driver);
        let cc = CcProvEngine::new(&sc(), pre.cc_triples, 4, 10);
        assert!(cc.query(AttrValueId::new(EntityId(9), 99).raw()).is_empty());
    }

    #[test]
    fn driver_branch_scans_less_than_spark_branch() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let q = trace.triples[trace.len() / 2].dst.raw();

        let spark = CcProvEngine::new(&s, pre.cc_triples.clone(), 16, 0);
        let before = s.metrics().snapshot();
        let _ = spark.query(q);
        let spark_rows = s.metrics().snapshot().since(&before).rows_scanned;

        let driver = CcProvEngine::new(&s, pre.cc_triples.clone(), 16, usize::MAX);
        let before = s.metrics().snapshot();
        let _ = driver.query(q);
        let driver_rows = s.metrics().snapshot().since(&before).rows_scanned;

        assert!(
            driver_rows <= spark_rows,
            "driver branch should scan no more rows: {driver_rows} vs {spark_rows}"
        );
    }
}
