//! CCProv — Algorithm 1.
//!
//! Preprocessing tags every triple with its weakly connected component id.
//! A query (1) resolves the queried item's component by a single-partition
//! lookup, (2) filters the component's triples (one full scan that
//! preserves the dst hash-partitioning), and (3) recursively queries only
//! that component — on the cluster when it holds ≥ τ triples, otherwise
//! collected to the driver (Spark job launch overhead dominates tiny jobs;
//! see §2.2 "Further Optimization").

use super::driver_rq::{bounded_closure, AncestorClosure, NativeClosure};
use super::engine::{ExecPath, ProvenanceEngine, QueryRequest, QueryResponse, QueryStats};
use super::result::Lineage;
use super::rq::rq_bfs;
use crate::minispark::{Dataset, MiniSpark, StageCost};
use crate::provenance::model::{CcTriple, ProvTriple};
use crate::util::ids::ComponentId;
use rustc_hash::FxHashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One memoized Find-Prov-Triples-In-Component output, plus the
/// deterministic [`StageCost`] its cold assemble charged. Hits replay that
/// cost, so a query's stats are identical whether it assembled the
/// component itself or found it hot (the batched-equals-sequential
/// property the harness tests pin); the engine-wide metrics ledger still
/// shows the scans actually saved.
struct AssembledCc {
    c_prov: Dataset<CcTriple>,
    volume: usize,
    cost: StageCost,
}

/// Algorithm 1 engine.
pub struct CcProvEngine {
    prov: Dataset<CcTriple>,
    tau: usize,
    closure: Arc<dyn AncestorClosure>,
    /// Hot-component memo: a small epoch-keyed LRU of assembles (see
    /// [`AssembledCc`] and [`AssembleMemo`](super::AssembleMemo)).
    assembled: Mutex<super::AssembleMemo<ComponentId, AssembledCc>>,
}

impl CcProvEngine {
    /// Build from preprocessed component-tagged triples. Takes a borrowed
    /// slice (typically `&pre.cc_triples` behind an `Arc<Preprocessed>`)
    /// and partitions it in one pass — no copy of the full `Vec`.
    pub fn new(
        sc: &MiniSpark,
        cc_triples: &[CcTriple],
        num_partitions: usize,
        tau: usize,
    ) -> Self {
        let prov = Dataset::hash_partitioned_from_slice(
            sc,
            cc_triples,
            num_partitions,
            super::KEY_TRIPLE_DST,
            |t: &CcTriple| t.triple.dst.raw(),
        );
        Self {
            prov,
            tau,
            closure: Arc::new(NativeClosure),
            assembled: Mutex::new(super::AssembleMemo::new(super::ASSEMBLE_MEMO_WAYS)),
        }
    }

    /// Wrap an already dst-partitioned component-tagged dataset — e.g. the
    /// demand-paged partitions of a segmented preprocessed store — without
    /// re-shuffling or copying it.
    ///
    /// Panics if the dataset carries no hash partitioning (the lookup cost
    /// argument depends on dst co-location).
    pub fn from_dataset(prov: Dataset<CcTriple>, tau: usize) -> Self {
        assert!(
            prov.partitioning().is_some(),
            "CcProvEngine::from_dataset requires a hash-partitioned dataset"
        );
        Self {
            prov,
            tau,
            closure: Arc::new(NativeClosure),
            assembled: Mutex::new(super::AssembleMemo::new(super::ASSEMBLE_MEMO_WAYS)),
        }
    }

    /// Swap the driver-side closure implementation (native / XLA).
    pub fn with_closure(mut self, closure: Arc<dyn AncestorClosure>) -> Self {
        self.closure = closure;
        self
    }

    /// Delta ingest: absorb an incremental-preprocessing delta without
    /// rebuilding the dataset. `retagged` maps pre-existing triples to
    /// their new component id (rows are keyed by `dst`, which retagging
    /// never changes, so they are patched in place in their partitions);
    /// `appended` rows are routed to their partitions by the existing key.
    pub fn with_delta(
        &self,
        retagged: &FxHashMap<ProvTriple, crate::util::ids::ComponentId>,
        appended: &[CcTriple],
    ) -> Self {
        let prov = if retagged.is_empty() {
            self.prov.clone()
        } else {
            let keys: Vec<u64> = retagged
                .keys()
                .map(|t| t.dst.raw())
                .collect::<rustc_hash::FxHashSet<u64>>()
                .into_iter()
                .collect();
            self.prov.patch_partitions(&keys, |t| {
                Some(match retagged.get(&t.triple) {
                    Some(&ccid) => CcTriple { triple: t.triple, ccid },
                    None => *t,
                })
            })
        };
        Self {
            prov: prov.append_partitioned(appended),
            tau: self.tau,
            closure: Arc::clone(&self.closure),
            // The delta may retag or extend any component: the successor
            // memo is one epoch later, so nothing stale can replay.
            assembled: Mutex::new(self.assembled.lock().expect("cc memo lock").successor()),
        }
    }

    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Spill the tagged-triple dataset to segment files
    /// ([`Dataset::spilled`]); a no-op clone without a memory budget.
    pub fn spilled(&self) -> anyhow::Result<Self> {
        Ok(Self {
            prov: self.prov.spilled("cc-prov")?,
            tau: self.tau,
            closure: Arc::clone(&self.closure),
            // A memoized component would pin pre-spill partitions resident:
            // the successor memo starts empty one epoch later.
            assembled: Mutex::new(self.assembled.lock().expect("cc memo lock").successor()),
        })
    }

    /// Find-Prov-Triples-In-Component, planned lazily: one fused stage
    /// (filter over the tagged dataset, dst-partitioning preserved) forced
    /// through the stage scheduler, memoized per component in a small LRU.
    /// The returned [`StageCost`] is the cold assemble's — replayed on
    /// hits.
    fn assemble(&self, ccid: ComponentId) -> (Dataset<CcTriple>, usize, StageCost) {
        if let Some(a) = self.assembled.lock().expect("cc memo lock").get(ccid) {
            return (a.c_prov.clone(), a.volume, a.cost);
        }
        let (c_prov, cost) =
            self.prov.lazy().filter(move |t| t.ccid == ccid).materialize_counted();
        let volume = c_prov.count();
        self.assembled
            .lock()
            .expect("cc memo lock")
            .put(ccid, AssembledCc { c_prov: c_prov.clone(), volume, cost });
        (c_prov, volume, cost)
    }

    /// Algorithm 1: lineage of `q` (see [`ProvenanceEngine::query`]).
    pub fn query(&self, q: u64) -> Lineage {
        self.execute(&QueryRequest::new(q)).lineage
    }
}

impl ProvenanceEngine for CcProvEngine {
    fn name(&self) -> &'static str {
        "ccprov"
    }

    fn execute(&self, req: &QueryRequest) -> QueryResponse {
        let q = req.item;
        let tau = req.tau_override.unwrap_or(self.tau);
        let mut stats = QueryStats::new("ccprov");

        // Find-Connected-Component: one partition scan. The deadline clock
        // starts here, so resolve/assemble time counts against the budget
        // even though only the recursion phase is cut.
        let t0 = Instant::now();
        let deadline = req.deadline.map(|d| t0 + d);
        let (rows, cost) = self.prov.lookup_counted(q);
        stats.partitions_scanned += cost.partitions;
        stats.rows_examined += cost.rows;
        stats.cache_hits += cost.cache_hits;
        stats.cache_misses += cost.cache_misses;
        let Some(first) = rows.first() else {
            stats.resolve = t0.elapsed();
            // Input value or unknown: no lineage.
            return QueryResponse { lineage: Lineage::empty(q), stats };
        };
        let ccid = first.ccid;
        stats.resolve = t0.elapsed();

        // Find-Prov-Triples-In-Component: a lazily planned, memoized
        // fused stage; the replayed cost attributes the same full scan of
        // the tagged dataset a cold run charges.
        let t1 = Instant::now();
        let (c_prov, volume, cost) = self.assemble(ccid);
        stats.partitions_scanned += cost.scan.partitions;
        stats.rows_examined += cost.scan.rows;
        stats.cache_hits += cost.scan.cache_hits;
        stats.cache_misses += cost.scan.cache_misses;
        stats.stages_run += cost.stages;
        stats.ops_fused += cost.fused;
        stats.intermediates_avoided += cost.intermediates_avoided;
        stats.assemble = t1.elapsed();

        let t2 = Instant::now();
        let lineage = if volume >= tau {
            // RQ on the cluster over the component's triples.
            stats.path = ExecPath::Cluster;
            let (lineage, bfs) =
                rq_bfs(&c_prov, |t| t.triple, q, req.max_depth, req.max_triples, deadline);
            stats.partitions_scanned += bfs.partitions;
            stats.rows_examined += bfs.rows;
            stats.cache_hits += bfs.cache_hits;
            stats.cache_misses += bfs.cache_misses;
            stats.bfs_rounds = bfs.rounds;
            stats.truncated = bfs.truncated;
            stats.completeness = bfs.completeness();
            lineage
        } else {
            // Collect to the driver and recurse locally.
            stats.path = ExecPath::Driver;
            let triples: Vec<ProvTriple> =
                c_prov.collect().into_iter().map(|t| t.triple).collect();
            stats.rows_collected = triples.len() as u64;
            if req.max_depth.is_none() && req.max_triples.is_none() && deadline.is_none() {
                self.closure.closure(&triples, q)
            } else {
                // Caps and deadlines require level-order expansion, which
                // the pluggable fixpoint closures can't provide (see
                // QueryRequest docs).
                let (lineage, bfs) =
                    bounded_closure(&triples, q, req.max_depth, req.max_triples, deadline);
                stats.bfs_rounds = bfs.rounds;
                stats.truncated = bfs.truncated;
                stats.completeness = bfs.completeness();
                lineage
            }
        };
        stats.recurse = t2.elapsed();
        QueryResponse { lineage, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::model::Trace;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::provenance::query::rq::RqEngine;
    use crate::util::ids::{AttrValueId, EntityId, OpId};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn ccprov_matches_rq_both_tau_branches() {
        let (trace, g, splits) = generate(&GeneratorConfig {
            scale_divisor: 2000,
            ..Default::default()
        });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let rq = RqEngine::new(&s, &trace.triples, 16);
        // Pick a handful of derived items.
        let queries: Vec<u64> = trace
            .triples
            .iter()
            .step_by(trace.len() / 8 + 1)
            .map(|t| t.dst.raw())
            .collect();
        for tau in [0usize, usize::MAX] {
            let cc = CcProvEngine::new(&s, &pre.cc_triples, 16, tau);
            for &q in &queries {
                assert_eq!(cc.query(q), rq.query(q), "q={q} tau={tau}");
            }
        }
    }

    #[test]
    fn unknown_item_is_empty() {
        let trace = Trace::new(vec![ProvTriple::new(
            AttrValueId::new(EntityId(0), 1),
            AttrValueId::new(EntityId(1), 1),
            OpId(0),
        )]);
        let (g, splits) = crate::workflow::curation::text_curation_workflow();
        let pre = preprocess(&trace, &g, &splits, 100, 100, WccImpl::Driver);
        let cc = CcProvEngine::new(&sc(), &pre.cc_triples, 4, 10);
        let resp = cc.execute(&QueryRequest::new(AttrValueId::new(EntityId(9), 99).raw()));
        assert!(resp.lineage.is_empty());
        // The resolve lookup still scanned one partition.
        assert_eq!(resp.stats.partitions_scanned, 1);
        assert_eq!(resp.stats.bfs_rounds, 0);
    }

    #[test]
    fn hot_component_memo_replays_identical_stats() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let cc = CcProvEngine::new(&s, &pre.cc_triples, 16, 0);
        let q = trace.triples[trace.len() / 3].dst.raw();
        let cold = cc.execute(&QueryRequest::new(q));
        let before = s.metrics().snapshot();
        let warm = cc.execute(&QueryRequest::new(q));
        assert_eq!(cold.lineage, warm.lineage);
        // Per-query attribution is deterministic: the hit replays the
        // cold assemble's stage cost.
        assert_eq!(cold.stats.partitions_scanned, warm.stats.partitions_scanned);
        assert_eq!(cold.stats.rows_examined, warm.stats.rows_examined);
        assert_eq!(warm.stats.stages_run, 1);
        assert!(warm.stats.summary().contains("stages=1"), "{}", warm.stats.summary());
        // ... while the engine-wide ledger shows the assemble never re-ran.
        assert_eq!(s.metrics().snapshot().since(&before).stages_run, 0);
    }

    #[test]
    fn memo_retains_multiple_hot_components() {
        // Interleaving a second component must not evict the first: the
        // single-slot memo this LRU replaced would re-assemble A after B.
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let cc = CcProvEngine::new(&s, &pre.cc_triples, 16, 0);
        let qa = trace.triples[trace.len() / 3].dst.raw();
        let qb = trace
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| pre.cc_of[n] != pre.cc_of[&qa])
            .expect("an item in a second component");
        let a_cold = cc.execute(&QueryRequest::new(qa));
        let _ = cc.execute(&QueryRequest::new(qb));
        let before = s.metrics().snapshot();
        let a_warm = cc.execute(&QueryRequest::new(qa));
        assert_eq!(a_cold.lineage, a_warm.lineage);
        assert_eq!(a_cold.stats.rows_examined, a_warm.stats.rows_examined);
        assert_eq!(
            s.metrics().snapshot().since(&before).stages_run,
            0,
            "warm component re-assembled after an interleaved query"
        );
    }

    #[test]
    fn ingest_invalidates_the_memo() {
        // A delta-ingested engine must re-assemble even a hot component —
        // its memo is one epoch later — so new rows show up immediately.
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let cc = CcProvEngine::new(&s, &pre.cc_triples, 16, 0);
        let t0 = trace.triples[trace.len() / 3];
        let q = t0.dst.raw();
        let cold = cc.execute(&QueryRequest::new(q));
        // Append one new parent of the queried item, tagged with its
        // existing component id.
        let ccid = pre
            .cc_triples
            .iter()
            .find(|t| t.triple.dst == t0.dst)
            .expect("queried item is tagged")
            .ccid;
        let extra = CcTriple {
            triple: ProvTriple::new(AttrValueId::new(EntityId(999_999), 1), t0.dst, OpId(77)),
            ccid,
        };
        let cc2 = cc.with_delta(&FxHashMap::default(), &[extra]);
        let before = s.metrics().snapshot();
        let fresh = cc2.execute(&QueryRequest::new(q));
        assert!(
            s.metrics().snapshot().since(&before).stages_run > 0,
            "the post-ingest engine must re-assemble, not replay the stale memo"
        );
        assert!(fresh.lineage.triples.contains(&extra.triple));
        assert_eq!(fresh.lineage.triples.len(), cold.lineage.triples.len() + 1);
    }

    #[test]
    fn driver_branch_scans_less_than_spark_branch() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let s = sc();
        let q = trace.triples[trace.len() / 2].dst.raw();

        let engine = CcProvEngine::new(&s, &pre.cc_triples, 16, 0);
        // τ per request: same engine, both branches.
        let spark = engine.execute(&QueryRequest::new(q).with_tau(0));
        let driver = engine.execute(&QueryRequest::new(q).with_tau(usize::MAX));
        assert_eq!(spark.lineage, driver.lineage);
        assert_eq!(spark.stats.path, ExecPath::Cluster);
        assert_eq!(driver.stats.path, ExecPath::Driver);
        assert!(driver.stats.rows_collected > 0);
        assert!(
            driver.stats.rows_examined <= spark.stats.rows_examined,
            "driver branch should scan no more rows: {} vs {}",
            driver.stats.rows_examined,
            spark.stats.rows_examined
        );
    }
}
