//! The query layer: one engine-agnostic interface over three engines.
//!
//! # The `ProvenanceEngine` trait
//!
//! Every engine implements [`ProvenanceEngine`]: `execute(&QueryRequest)
//! -> QueryResponse`. A [`QueryRequest`] names the queried attribute-value
//! plus options (max BFS depth, best-effort triple cap, per-query τ
//! override); a [`QueryResponse`] bundles the [`Lineage`] with a
//! [`QueryStats`] record — partitions scanned, rows examined, BFS rounds,
//! driver-vs-cluster path, per-phase wall time. Those are the quantities
//! the paper's Tables 10–12 are really measuring, attributed to a single
//! query rather than smeared across the engine-wide metrics.
//!
//! The engines:
//!
//! * [`RqEngine`] — the recursive-querying baseline (§2.1): BFS over the
//!   *whole* dst-partitioned triple dataset, one multi-lookup job per
//!   frontier round.
//! * [`CcProvEngine`] — Algorithm 1: resolve the component, filter it out,
//!   then recurse over the component only (driver-side if < τ).
//! * [`CsProvEngine`] — Algorithm 2: resolve the connected set, walk the
//!   set-dependency graph for the set-lineage, assemble the minimal triple
//!   volume by partition-pruned lookups, then recurse (driver-side if < τ).
//!
//! All three return identical [`Lineage`]s for any request — a
//! cross-engine property test drives them through `&dyn ProvenanceEngine`
//! to enforce it. They differ only in cost, which [`QueryStats`] exposes.
//!
//! # Sessions
//!
//! Callers normally don't touch engines directly: `harness::ProvSession`
//! owns all three over one `Arc`-shared preprocessed trace, routes each
//! request to an engine (`harness::EngineRouter`, including an `Auto`
//! policy keyed on component size), and fans batches across the worker
//! pool with `query_many`.

use crate::minispark::KeyTag;

/// Partitioning-key identities shared by the engines (see [`KeyTag`]).
/// Datasets hash-partitioned on the same tag with the same partition count
/// are co-partitioned, so re-partitions and partition-aware unions across
/// them elide the shuffle.
///
/// The derived item (`triple.dst`) of a provenance triple — RQ's and
/// CCProv's layout, and CSProv's recursive phase.
pub const KEY_TRIPLE_DST: KeyTag = KeyTag::named("prov.triple.dst");
/// The connected-set id of the derived item (`dst_csid`) — CSProv's
/// storage layout for triples and set dependencies.
pub const KEY_DST_CSID: KeyTag = KeyTag::named("prov.dst_csid");

/// Number of hot assembles each engine retains ([`AssembleMemo`]).
pub(crate) const ASSEMBLE_MEMO_WAYS: usize = 8;

/// A small epoch-keyed LRU of hot assembles.
///
/// CCProv memoizes Find-Prov-Triples-In-Component and CSProv the pruned
/// `cs_provRDD` fetch. A single hot slot thrashes under interleaved
/// workloads (querying components A, B, A re-assembles A), so each engine
/// keeps up to [`ASSEMBLE_MEMO_WAYS`] entries in LRU order. Every entry is
/// stamped with the epoch it was memoized at and lookups only match the
/// current epoch: delta ingest hands the successor engine a memo one epoch
/// later ([`AssembleMemo::successor`]), so nothing assembled against the
/// pre-ingest datasets can ever replay after an ingest.
pub(crate) struct AssembleMemo<K, V> {
    cap: usize,
    epoch: u64,
    /// `(epoch, key, value)`, least-recently used first.
    entries: Vec<(u64, K, V)>,
}

impl<K: PartialEq + Copy, V> AssembleMemo<K, V> {
    pub(crate) fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), epoch: 0, entries: Vec::new() }
    }

    /// The memo for the engine a delta ingest (or a spill) produces: one
    /// epoch later and empty, so every previously memoized assemble is
    /// stale by construction.
    pub(crate) fn successor(&self) -> Self {
        Self { cap: self.cap, epoch: self.epoch + 1, entries: Vec::new() }
    }

    /// Current-epoch lookup; a hit is promoted to most-recently used.
    pub(crate) fn get(&mut self, key: K) -> Option<&V> {
        let i = self.entries.iter().position(|(e, k, _)| *e == self.epoch && *k == key)?;
        let hit = self.entries.remove(i);
        self.entries.push(hit);
        self.entries.last().map(|(_, _, v)| v)
    }

    /// Insert at most-recently used, evicting the least-recently used
    /// entry beyond capacity (stale-epoch entries and any previous copy of
    /// the key are dropped first).
    pub(crate) fn put(&mut self, key: K, value: V) {
        self.entries.retain(|(e, k, _)| *e == self.epoch && *k != key);
        if self.entries.len() >= self.cap {
            self.entries.remove(0);
        }
        self.entries.push((self.epoch, key, value));
    }
}

pub mod ccprov;
pub mod csprov;
pub mod driver_rq;
pub mod engine;
pub mod result;
pub mod rq;

pub use ccprov::CcProvEngine;
pub use csprov::{CsDelta, CsProvEngine};
pub use driver_rq::{AncestorClosure, NativeClosure};
pub use engine::{
    Completeness, ExecPath, ProvenanceEngine, QueryOutcome, QueryRequest, QueryResponse,
    QueryStats,
};
pub use result::Lineage;
pub use rq::RqEngine;

#[cfg(test)]
mod tests {
    use super::AssembleMemo;

    #[test]
    fn memo_is_lru_with_capacity() {
        let mut m: AssembleMemo<u64, &'static str> = AssembleMemo::new(2);
        m.put(1, "a");
        m.put(2, "b");
        assert_eq!(m.get(1).copied(), Some("a")); // promotes 1 to MRU
        m.put(3, "c"); // evicts 2, the LRU
        assert!(m.get(2).is_none());
        assert_eq!(m.get(1).copied(), Some("a"));
        assert_eq!(m.get(3).copied(), Some("c"));
    }

    #[test]
    fn successor_epoch_invalidates_everything() {
        let mut m: AssembleMemo<u64, u32> = AssembleMemo::new(4);
        m.put(7, 70);
        assert_eq!(m.get(7).copied(), Some(70));
        let mut next = m.successor();
        assert!(next.get(7).is_none(), "pre-ingest entries must be stale");
        next.put(7, 71);
        assert_eq!(next.get(7).copied(), Some(71));
    }
}
