//! The three provenance query engines:
//!
//! * [`RqEngine`] — the recursive-querying baseline (§2.1): BFS over the
//!   *whole* dst-partitioned triple dataset, one multi-lookup job per
//!   frontier round.
//! * [`CcProvEngine`] — Algorithm 1: resolve the component, filter it out,
//!   then recurse over the component only (driver-side if < τ).
//! * [`CsProvEngine`] — Algorithm 2: resolve the connected set, walk the
//!   set-dependency graph for the set-lineage, assemble the minimal triple
//!   volume by partition-pruned lookups, then recurse (driver-side if < τ).
//!
//! All three return identical [`Lineage`]s — a cross-engine property test
//! enforces it.

pub mod ccprov;
pub mod csprov;
pub mod driver_rq;
pub mod result;
pub mod rq;

pub use ccprov::CcProvEngine;
pub use csprov::CsProvEngine;
pub use driver_rq::{AncestorClosure, NativeClosure};
pub use result::Lineage;
pub use rq::RqEngine;
