//! The three provenance query engines:
//!
//! * [`RqEngine`] — the recursive-querying baseline (§2.1): BFS over the
//!   *whole* dst-partitioned triple dataset, one multi-lookup job per
//!   frontier round.
//! * [`CcProvEngine`] — Algorithm 1: resolve the component, filter it out,
//!   then recurse over the component only (driver-side if < τ).
//! * [`CsProvEngine`] — Algorithm 2: resolve the connected set, walk the
//!   set-dependency graph for the set-lineage, assemble the minimal triple
//!   volume by partition-pruned lookups, then recurse (driver-side if < τ).
//!
//! All three return identical [`Lineage`]s — a cross-engine property test
//! enforces it.

use crate::minispark::KeyTag;

/// Partitioning-key identities shared by the engines (see [`KeyTag`]).
/// Datasets hash-partitioned on the same tag with the same partition count
/// are co-partitioned, so re-partitions and partition-aware unions across
/// them elide the shuffle.
///
/// The derived item (`triple.dst`) of a provenance triple — RQ's and
/// CCProv's layout, and CSProv's recursive phase.
pub const KEY_TRIPLE_DST: KeyTag = KeyTag::named("prov.triple.dst");
/// The connected-set id of the derived item (`dst_csid`) — CSProv's
/// storage layout for triples and set dependencies.
pub const KEY_DST_CSID: KeyTag = KeyTag::named("prov.dst_csid");

pub mod ccprov;
pub mod csprov;
pub mod driver_rq;
pub mod result;
pub mod rq;

pub use ccprov::CcProvEngine;
pub use csprov::CsProvEngine;
pub use driver_rq::{AncestorClosure, NativeClosure};
pub use result::Lineage;
pub use rq::RqEngine;
