//! The query layer: one engine-agnostic interface over three engines.
//!
//! # The `ProvenanceEngine` trait
//!
//! Every engine implements [`ProvenanceEngine`]: `execute(&QueryRequest)
//! -> QueryResponse`. A [`QueryRequest`] names the queried attribute-value
//! plus options (max BFS depth, best-effort triple cap, per-query τ
//! override); a [`QueryResponse`] bundles the [`Lineage`] with a
//! [`QueryStats`] record — partitions scanned, rows examined, BFS rounds,
//! driver-vs-cluster path, per-phase wall time. Those are the quantities
//! the paper's Tables 10–12 are really measuring, attributed to a single
//! query rather than smeared across the engine-wide metrics.
//!
//! The engines:
//!
//! * [`RqEngine`] — the recursive-querying baseline (§2.1): BFS over the
//!   *whole* dst-partitioned triple dataset, one multi-lookup job per
//!   frontier round.
//! * [`CcProvEngine`] — Algorithm 1: resolve the component, filter it out,
//!   then recurse over the component only (driver-side if < τ).
//! * [`CsProvEngine`] — Algorithm 2: resolve the connected set, walk the
//!   set-dependency graph for the set-lineage, assemble the minimal triple
//!   volume by partition-pruned lookups, then recurse (driver-side if < τ).
//!
//! All three return identical [`Lineage`]s for any request — a
//! cross-engine property test drives them through `&dyn ProvenanceEngine`
//! to enforce it. They differ only in cost, which [`QueryStats`] exposes.
//!
//! # Sessions
//!
//! Callers normally don't touch engines directly: `harness::ProvSession`
//! owns all three over one `Arc`-shared preprocessed trace, routes each
//! request to an engine (`harness::EngineRouter`, including an `Auto`
//! policy keyed on component size), and fans batches across the worker
//! pool with `query_many`.

use crate::minispark::KeyTag;

/// Partitioning-key identities shared by the engines (see [`KeyTag`]).
/// Datasets hash-partitioned on the same tag with the same partition count
/// are co-partitioned, so re-partitions and partition-aware unions across
/// them elide the shuffle.
///
/// The derived item (`triple.dst`) of a provenance triple — RQ's and
/// CCProv's layout, and CSProv's recursive phase.
pub const KEY_TRIPLE_DST: KeyTag = KeyTag::named("prov.triple.dst");
/// The connected-set id of the derived item (`dst_csid`) — CSProv's
/// storage layout for triples and set dependencies.
pub const KEY_DST_CSID: KeyTag = KeyTag::named("prov.dst_csid");

pub mod ccprov;
pub mod csprov;
pub mod driver_rq;
pub mod engine;
pub mod result;
pub mod rq;

pub use ccprov::CcProvEngine;
pub use csprov::{CsDelta, CsProvEngine};
pub use driver_rq::{AncestorClosure, NativeClosure};
pub use engine::{
    Completeness, ExecPath, ProvenanceEngine, QueryOutcome, QueryRequest, QueryResponse,
    QueryStats,
};
pub use result::Lineage;
pub use rq::RqEngine;
