//! Driver-side recursive querying (the `RQ_on_DriverMachine` branch of
//! Algorithms 1–2): once a small triple volume is collected, compute the
//! ancestor closure locally.
//!
//! The closure is pluggable: [`NativeClosure`] is the pure-Rust reverse-BFS;
//! `runtime::XlaClosure` runs the same fixpoint as an AOT-compiled HLO
//! reachability kernel (see `python/compile/model.py::reach_fixpoint`).

use super::result::Lineage;
use super::rq::BfsStats;
use crate::provenance::model::ProvTriple;
use rustc_hash::FxHashMap;
use std::time::Instant;

/// Strategy for computing the ancestor closure of a collected triple pile.
pub trait AncestorClosure: Send + Sync {
    /// All lineage triples of `q` within `triples`.
    fn closure(&self, triples: &[ProvTriple], q: u64) -> Lineage;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Reverse-BFS over a dst-indexed adjacency map.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeClosure;

impl AncestorClosure for NativeClosure {
    fn closure(&self, triples: &[ProvTriple], q: u64) -> Lineage {
        // The uncapped case of the bounded traversal below; the lineage is
        // canonicalized, so the traversal order cannot show through.
        bounded_closure(triples, q, None, None, None).0
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Driver-side closure honoring [`QueryRequest`](super::QueryRequest)
/// depth/triple caps and the absolute deadline: a strict level-by-level
/// reverse BFS whose rounds mirror the cluster engines' lookup rounds
/// exactly, so a *capped or deadline-cut* lineage is identical whichever
/// engine (and whichever τ branch) answers it. The deadline is checked at
/// the top of each round, exactly like `rq_bfs` — a run cut after `k`
/// rounds equals a `max_depth = k` query. Returns the lineage plus the
/// same [`BfsStats`] the cluster path reports (its `partitions` / `rows`
/// stay zero: there are no lookup jobs on the driver).
pub fn bounded_closure(
    triples: &[ProvTriple],
    q: u64,
    max_depth: Option<u32>,
    max_triples: Option<usize>,
    deadline: Option<Instant>,
) -> (Lineage, BfsStats) {
    let mut by_dst: FxHashMap<u64, Vec<u32>> =
        FxHashMap::with_capacity_and_hasher(triples.len(), Default::default());
    for (i, t) in triples.iter().enumerate() {
        by_dst.entry(t.dst.raw()).or_default().push(i as u32);
    }
    let mut out: Vec<ProvTriple> = Vec::new();
    let mut visited: rustc_hash::FxHashSet<u64> = rustc_hash::FxHashSet::default();
    visited.insert(q);
    let mut frontier = vec![q];
    let mut stats = BfsStats::default();
    while !frontier.is_empty() {
        if let Some(t) = deadline {
            if Instant::now() >= t {
                stats.deadline_hit = true;
                stats.frontier_remaining = frontier.len();
                break;
            }
        }
        if let Some(d) = max_depth {
            if stats.rounds >= d {
                stats.truncated = true;
                break;
            }
        }
        let mut next = Vec::new();
        for node in &frontier {
            for &i in by_dst.get(node).into_iter().flatten() {
                let t = triples[i as usize];
                out.push(t);
                if visited.insert(t.src.raw()) {
                    next.push(t.src.raw());
                }
            }
        }
        stats.rounds += 1;
        if let Some(m) = max_triples {
            if out.len() >= m {
                stats.truncated = !next.is_empty();
                break;
            }
        }
        frontier = next;
    }
    (Lineage::from_triples(q, out), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn t(s: u64, d: u64) -> ProvTriple {
        ProvTriple::new(
            AttrValueId::new(EntityId(0), s),
            AttrValueId::new(EntityId(0), d),
            OpId(0),
        )
    }

    fn raw(s: u64) -> u64 {
        AttrValueId::new(EntityId(0), s).raw()
    }

    #[test]
    fn closure_follows_paths_backwards() {
        // 1 → 2 → 4 ; 3 → 4 ; 4 → 5 ; unrelated 7 → 8
        let triples = vec![t(1, 2), t(2, 4), t(3, 4), t(4, 5), t(7, 8)];
        let l = NativeClosure.closure(&triples, raw(5));
        assert_eq!(l.triples.len(), 4);
        assert_eq!(l.ancestors, vec![raw(1), raw(2), raw(3), raw(4)]);
    }

    #[test]
    fn closure_of_source_is_empty() {
        let triples = vec![t(1, 2)];
        let l = NativeClosure.closure(&triples, raw(1));
        assert!(l.is_empty());
    }

    #[test]
    fn closure_handles_diamonds_without_duplication() {
        // 1 → {2,3} → 4 (diamond)
        let triples = vec![t(1, 2), t(1, 3), t(2, 4), t(3, 4)];
        let l = NativeClosure.closure(&triples, raw(4));
        assert_eq!(l.triples.len(), 4);
        assert_eq!(l.ancestors, vec![raw(1), raw(2), raw(3)]);
    }

    #[test]
    fn closure_tolerates_cycles() {
        // Provenance is a DAG in theory; be robust anyway: 1 ↔ 2 → 3.
        let triples = vec![t(1, 2), t(2, 1), t(2, 3)];
        let l = NativeClosure.closure(&triples, raw(3));
        assert_eq!(l.ancestors, vec![raw(1), raw(2)]);
    }

    #[test]
    fn bounded_closure_unbounded_matches_native() {
        let triples = vec![t(1, 2), t(2, 4), t(3, 4), t(4, 5), t(7, 8)];
        let (l, stats) = bounded_closure(&triples, raw(5), None, None, None);
        assert_eq!(l, NativeClosure.closure(&triples, raw(5)));
        assert!(!stats.truncated);
        assert!(stats.completeness().exhausted);
        // 5 ← 4 ← {2,3} ← 1, plus one empty-frontier-detecting round.
        assert_eq!(stats.rounds, 4);
    }

    #[test]
    fn bounded_closure_depth_cap() {
        // Chain 1 → 2 → 3 → 4 → 5.
        let triples = vec![t(1, 2), t(2, 3), t(3, 4), t(4, 5)];
        let (l, stats) = bounded_closure(&triples, raw(5), Some(2), None, None);
        assert_eq!(stats.rounds, 2);
        assert!(stats.truncated);
        assert_eq!(l.ancestors, vec![raw(3), raw(4)]);
        // Depth 0: nothing expanded, flagged truncated.
        let (l0, s0) = bounded_closure(&triples, raw(5), Some(0), None, None);
        assert!(l0.is_empty());
        assert_eq!(s0.rounds, 0);
        assert!(s0.truncated);
    }

    #[test]
    fn bounded_closure_triple_cap() {
        let triples = vec![t(1, 2), t(2, 3), t(3, 4), t(4, 5)];
        let (l, stats) = bounded_closure(&triples, raw(5), None, Some(2), None);
        assert!(stats.truncated);
        assert_eq!(l.triples.len(), 2);
        // A cap the lineage never reaches is not a truncation.
        let (full, stats) = bounded_closure(&triples, raw(5), None, Some(5), None);
        assert!(!stats.truncated);
        assert_eq!(full.triples.len(), 4);
    }

    #[test]
    fn bounded_closure_deadline_cut_is_a_depth_prefix() {
        let triples = vec![t(1, 2), t(2, 3), t(3, 4), t(4, 5)];
        let expired = Instant::now();
        let (l, stats) = bounded_closure(&triples, raw(5), None, None, Some(expired));
        assert!(l.is_empty());
        assert!(stats.deadline_hit);
        assert!(!stats.truncated);
        let c = stats.completeness();
        assert!(!c.exhausted);
        assert_eq!(c.rounds_done, 0);
        assert_eq!(c.frontier_remaining, 1);
        // Equal to the max_depth = rounds_done query by construction.
        let (prefix, _) = bounded_closure(&triples, raw(5), Some(0), None, None);
        assert_eq!(l, prefix);
    }
}
