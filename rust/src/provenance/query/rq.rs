//! RQ — the recursive-querying baseline (paper §2.1).
//!
//! The triple dataset is hash-partitioned on `dst`; each BFS round issues
//! one multi-lookup job that scans at most `|frontier|` distinct partitions
//! (data-items co-located in one partition are resolved by a single scan,
//! exactly the cost argument of §2.1). The total cost therefore grows with
//! the *whole dataset's* partition sizes — which is why RQ degrades as the
//! trace scales (Tables 10–12) and why CCProv/CSProv shrink the data first.

use super::result::Lineage;
use crate::minispark::{Dataset, MiniSpark};
use crate::provenance::model::{ProvTriple, Trace};
use rustc_hash::FxHashSet;

/// Generic recursive querying over any dst-partitioned row type.
/// `to_triple` projects a row to its provenance triple.
pub fn rq_on_spark_generic<T: Send + Sync + Clone + 'static>(
    ds: &Dataset<T>,
    to_triple: impl Fn(&T) -> ProvTriple + Send + Sync,
    q: u64,
) -> Lineage {
    let mut collected: Vec<ProvTriple> = Vec::new();
    let mut visited: FxHashSet<u64> = FxHashSet::default();
    visited.insert(q);
    let mut frontier = vec![q];
    while !frontier.is_empty() {
        let rows = ds.multi_lookup(&frontier);
        let mut next = Vec::new();
        for r in &rows {
            let t = to_triple(r);
            if visited.insert(t.src.raw()) {
                next.push(t.src.raw());
            }
            collected.push(t);
        }
        frontier = next;
    }
    Lineage::from_triples(q, collected)
}

/// The RQ baseline engine: recursive querying over the full trace.
pub struct RqEngine {
    prov: Dataset<ProvTriple>,
}

impl RqEngine {
    /// Load the trace into a dst-partitioned dataset.
    pub fn new(sc: &MiniSpark, trace: &Trace, num_partitions: usize) -> Self {
        let prov = Dataset::from_vec(sc, trace.triples.clone(), num_partitions)
            .hash_partition_by_tagged(num_partitions, super::KEY_TRIPLE_DST, |t: &ProvTriple| {
                t.dst.raw()
            })
            .cache();
        Self { prov }
    }

    /// Trace the full lineage of `q`.
    pub fn query(&self, q: u64) -> Lineage {
        rq_on_spark_generic(&self.prov, |t| *t, q)
    }

    /// The underlying dataset (tests / benches).
    pub fn dataset(&self) -> &Dataset<ProvTriple> {
        &self.prov
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn t(s: u64, d: u64) -> ProvTriple {
        ProvTriple::new(
            AttrValueId::new(EntityId(0), s),
            AttrValueId::new(EntityId(1), d),
            OpId(0),
        )
    }

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn rq_matches_driver_closure() {
        // Layered DAG: e0 serials feed e1 serials.
        let triples: Vec<ProvTriple> =
            (0..100).map(|i| t(i, i / 2)).chain((0..50).map(|i| t(i + 100, i))).collect();
        let trace = Trace::new(triples.clone());
        let engine = RqEngine::new(&sc(), &trace, 8);
        for q in [
            AttrValueId::new(EntityId(1), 0).raw(),
            AttrValueId::new(EntityId(1), 7).raw(),
            AttrValueId::new(EntityId(1), 49).raw(),
        ] {
            let a = engine.query(q);
            let b = NativeClosure.closure(&triples, q);
            assert_eq!(a, b, "q={q}");
        }
    }

    #[test]
    fn rq_unknown_item_empty() {
        let trace = Trace::new(vec![t(1, 2)]);
        let engine = RqEngine::new(&sc(), &trace, 4);
        let l = engine.query(AttrValueId::new(EntityId(5), 99).raw());
        assert!(l.is_empty());
    }

    #[test]
    fn rq_rounds_equal_lineage_depth() {
        // Same-entity chain 5 → 4 → 3 → 2 → 1 → 0: one lookup job per level.
        let e = EntityId(0);
        let triples: Vec<ProvTriple> = (0..5)
            .map(|i| {
                ProvTriple::new(
                    AttrValueId::new(e, i + 1),
                    AttrValueId::new(e, i),
                    OpId(0),
                )
            })
            .collect();
        let trace = Trace::new(triples);
        let s = sc();
        let engine = RqEngine::new(&s, &trace, 4);
        let before = s.metrics().snapshot();
        let l = engine.query(AttrValueId::new(e, 0).raw());
        let delta = s.metrics().snapshot().since(&before);
        assert_eq!(l.ancestors.len(), 5);
        // depth+1 lookup jobs (last round finds nothing new).
        assert!(delta.jobs >= 5 && delta.jobs <= 7, "jobs={}", delta.jobs);
    }
}
