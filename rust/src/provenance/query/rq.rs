//! RQ — the recursive-querying baseline (paper §2.1).
//!
//! The triple dataset is hash-partitioned on `dst`; each BFS round issues
//! one multi-lookup job that scans at most `|frontier|` distinct partitions
//! (data-items co-located in one partition are resolved by a single scan,
//! exactly the cost argument of §2.1). The total cost therefore grows with
//! the *whole dataset's* partition sizes — which is why RQ degrades as the
//! trace scales (Tables 10–12) and why CCProv/CSProv shrink the data first.

use super::engine::{
    Completeness, ExecPath, ProvenanceEngine, QueryRequest, QueryResponse, QueryStats,
};
use super::result::Lineage;
use crate::minispark::{Dataset, MiniSpark};
use crate::provenance::model::ProvTriple;
use rustc_hash::FxHashSet;
use std::time::Instant;

/// Cost of one recursive-querying run: rounds executed, partitions and rows
/// scanned by the lookup jobs, whether a request cap stopped it early, and
/// the deadline bound (how much frontier was left when time ran out).
#[derive(Debug, Clone, Copy, Default)]
pub struct BfsStats {
    pub rounds: u32,
    pub partitions: u64,
    pub rows: u64,
    /// Partition fetches served warm from the cache (spilled datasets only).
    pub cache_hits: u64,
    /// Segments paged in from disk to answer the lookups.
    pub cache_misses: u64,
    pub truncated: bool,
    /// Frontier items still unexpanded when the deadline stopped the
    /// traversal (meaningful only with `deadline_hit`).
    pub frontier_remaining: usize,
    /// True when the deadline — not a cap or the fixpoint — ended the run.
    pub deadline_hit: bool,
}

impl BfsStats {
    /// The [`Completeness`] bound this run supports: the complete bound
    /// unless the deadline cut the traversal, in which case the answer
    /// covers exactly `rounds` fully-expanded levels.
    pub fn completeness(&self) -> Completeness {
        if self.deadline_hit {
            Completeness {
                rounds_done: self.rounds,
                frontier_remaining: self.frontier_remaining,
                exhausted: false,
            }
        } else {
            Completeness::default()
        }
    }
}

/// Recursive querying over any dst-partitioned row type, with per-query
/// cost accounting, the [`QueryRequest`] depth / triple caps, and an
/// optional absolute deadline.
///
/// The deadline is checked at the same place as the depth cap — the top of
/// each round — so a run cut after `k` rounds returns *exactly* the
/// lineage of a `max_depth = k` query: the degraded answer is a
/// well-defined prefix. `to_triple` projects a row to its provenance
/// triple.
pub fn rq_bfs<T: Send + Sync + Clone + 'static>(
    ds: &Dataset<T>,
    to_triple: impl Fn(&T) -> ProvTriple + Send + Sync,
    q: u64,
    max_depth: Option<u32>,
    max_triples: Option<usize>,
    deadline: Option<Instant>,
) -> (Lineage, BfsStats) {
    let mut stats = BfsStats::default();
    let mut collected: Vec<ProvTriple> = Vec::new();
    let mut visited: FxHashSet<u64> = FxHashSet::default();
    visited.insert(q);
    let mut frontier = vec![q];
    // Pins held by the previous round's readahead (see Dataset::prefetch):
    // warmed partitions stay unevictable until the round that asked for
    // them has run its lookup.
    let mut readahead: Option<crate::storage::PrefetchBatch> = None;
    while !frontier.is_empty() {
        if let Some(t) = deadline {
            if Instant::now() >= t {
                stats.deadline_hit = true;
                stats.frontier_remaining = frontier.len();
                break;
            }
        }
        if let Some(d) = max_depth {
            if stats.rounds >= d {
                stats.truncated = true;
                break;
            }
        }
        let (rows, cost) = ds.multi_lookup_counted(&frontier);
        // This round consumed its readahead; release the pins.
        drop(readahead.take());
        stats.rounds += 1;
        stats.partitions += cost.partitions;
        stats.rows += cost.rows;
        stats.cache_hits += cost.cache_hits;
        stats.cache_misses += cost.cache_misses;
        let mut next = Vec::new();
        for r in &rows {
            let t = to_triple(r);
            if visited.insert(t.src.raw()) {
                next.push(t.src.raw());
            }
            collected.push(t);
        }
        if let Some(m) = max_triples {
            if collected.len() >= m {
                stats.truncated = !next.is_empty();
                break;
            }
        }
        // The next frontier is known a full round early: hand it to the
        // background pool so its partitions warm while this loop's driver
        // work (and the next job's launch overhead) runs.
        readahead = ds.prefetch(&next);
        frontier = next;
    }
    (Lineage::from_triples(q, collected), stats)
}

/// Generic unbounded recursive querying (the pre-stats entry point; kept
/// for callers that only want the lineage).
pub fn rq_on_spark_generic<T: Send + Sync + Clone + 'static>(
    ds: &Dataset<T>,
    to_triple: impl Fn(&T) -> ProvTriple + Send + Sync,
    q: u64,
) -> Lineage {
    rq_bfs(ds, to_triple, q, None, None, None).0
}

/// The RQ baseline engine: recursive querying over the full trace.
pub struct RqEngine {
    prov: Dataset<ProvTriple>,
}

impl RqEngine {
    /// Load the trace's triples into a dst-partitioned dataset. Takes a
    /// borrowed slice (typically out of an `Arc<Trace>`) and partitions it
    /// in one pass — no intermediate copy of the full triple `Vec`.
    pub fn new(sc: &MiniSpark, triples: &[ProvTriple], num_partitions: usize) -> Self {
        let prov = Dataset::hash_partitioned_from_slice(
            sc,
            triples,
            num_partitions,
            super::KEY_TRIPLE_DST,
            |t: &ProvTriple| t.dst.raw(),
        );
        Self { prov }
    }

    /// Wrap an already dst-partitioned triple dataset — e.g. one built by
    /// a lazy plan ([`crate::minispark::LazyDataset`]) — without
    /// re-shuffling it. The differential DAG suite uses this to drive the
    /// BFS over lazily assembled datasets.
    ///
    /// Panics if the dataset carries no hash partitioning (RQ's lookup
    /// cost argument depends on dst co-location).
    pub fn from_dataset(prov: Dataset<ProvTriple>) -> Self {
        assert!(
            prov.partitioning().is_some(),
            "RqEngine::from_dataset requires a hash-partitioned dataset"
        );
        Self { prov }
    }

    /// Delta ingest: a new engine over the old dataset plus `appended`
    /// triples, routed into their dst partitions in place
    /// ([`Dataset::append_partitioned`]) — RQ rows carry no preprocessing
    /// tags, so an append is all a delta ever needs here.
    pub fn with_appended(&self, appended: &[ProvTriple]) -> Self {
        Self { prov: self.prov.append_partitioned(appended) }
    }

    /// Spill the triple dataset to segment files ([`Dataset::spilled`]);
    /// queries then page partitions back through the context's
    /// byte-budgeted cache. A no-op clone when the context has no
    /// memory budget.
    pub fn spilled(&self) -> anyhow::Result<Self> {
        Ok(Self { prov: self.prov.spilled("rq-prov")? })
    }

    /// Trace the full lineage of `q` (see [`ProvenanceEngine::query`]).
    pub fn query(&self, q: u64) -> Lineage {
        self.execute(&QueryRequest::new(q)).lineage
    }

    /// The underlying dataset (tests / benches).
    pub fn dataset(&self) -> &Dataset<ProvTriple> {
        &self.prov
    }
}

impl ProvenanceEngine for RqEngine {
    fn name(&self) -> &'static str {
        "rq"
    }

    /// RQ has no resolve/assemble phases and no driver path; `tau_override`
    /// is ignored.
    fn execute(&self, req: &QueryRequest) -> QueryResponse {
        let mut stats = QueryStats::new("rq");
        stats.path = ExecPath::Cluster;
        let t0 = Instant::now();
        let deadline = req.deadline.map(|d| t0 + d);
        let (lineage, bfs) =
            rq_bfs(&self.prov, |t| *t, req.item, req.max_depth, req.max_triples, deadline);
        stats.partitions_scanned = bfs.partitions;
        stats.rows_examined = bfs.rows;
        stats.cache_hits = bfs.cache_hits;
        stats.cache_misses = bfs.cache_misses;
        stats.bfs_rounds = bfs.rounds;
        stats.truncated = bfs.truncated;
        stats.completeness = bfs.completeness();
        stats.recurse = t0.elapsed();
        QueryResponse { lineage, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::model::Trace;
    use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn t(s: u64, d: u64) -> ProvTriple {
        ProvTriple::new(
            AttrValueId::new(EntityId(0), s),
            AttrValueId::new(EntityId(1), d),
            OpId(0),
        )
    }

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn rq_matches_driver_closure() {
        // Layered DAG: e0 serials feed e1 serials.
        let triples: Vec<ProvTriple> =
            (0..100).map(|i| t(i, i / 2)).chain((0..50).map(|i| t(i + 100, i))).collect();
        let trace = Trace::new(triples.clone());
        let engine = RqEngine::new(&sc(), &trace.triples, 8);
        for q in [
            AttrValueId::new(EntityId(1), 0).raw(),
            AttrValueId::new(EntityId(1), 7).raw(),
            AttrValueId::new(EntityId(1), 49).raw(),
        ] {
            let a = engine.query(q);
            let b = NativeClosure.closure(&triples, q);
            assert_eq!(a, b, "q={q}");
        }
    }

    #[test]
    fn rq_unknown_item_empty() {
        let trace = Trace::new(vec![t(1, 2)]);
        let engine = RqEngine::new(&sc(), &trace.triples, 4);
        let resp = engine.execute(&QueryRequest::new(
            AttrValueId::new(EntityId(5), 99).raw(),
        ));
        assert!(resp.lineage.is_empty());
        // The first round still scanned one partition looking for it.
        assert_eq!(resp.stats.bfs_rounds, 1);
        assert_eq!(resp.stats.partitions_scanned, 1);
    }

    #[test]
    fn rq_rounds_equal_lineage_depth() {
        // Same-entity chain 5 → 4 → 3 → 2 → 1 → 0: one lookup job per level.
        let e = EntityId(0);
        let triples: Vec<ProvTriple> = (0..5)
            .map(|i| {
                ProvTriple::new(
                    AttrValueId::new(e, i + 1),
                    AttrValueId::new(e, i),
                    OpId(0),
                )
            })
            .collect();
        let trace = Trace::new(triples);
        let s = sc();
        let engine = RqEngine::new(&s, &trace.triples, 4);
        let before = s.metrics().snapshot();
        let resp = engine.execute(&QueryRequest::new(AttrValueId::new(e, 0).raw()));
        let delta = s.metrics().snapshot().since(&before);
        assert_eq!(resp.lineage.ancestors.len(), 5);
        // depth+1 lookup jobs (last round finds nothing new).
        assert!(delta.jobs >= 5 && delta.jobs <= 7, "jobs={}", delta.jobs);
        assert_eq!(resp.stats.bfs_rounds, 6);
        // Per-query stats agree with the engine-wide counters.
        assert_eq!(resp.stats.partitions_scanned, delta.partitions_scanned);
        assert_eq!(resp.stats.rows_examined, delta.rows_scanned);
    }

    #[test]
    fn rq_depth_and_triple_caps() {
        let e = EntityId(0);
        let triples: Vec<ProvTriple> = (0..6)
            .map(|i| {
                ProvTriple::new(
                    AttrValueId::new(e, i + 1),
                    AttrValueId::new(e, i),
                    OpId(0),
                )
            })
            .collect();
        let trace = Trace::new(triples);
        let engine = RqEngine::new(&sc(), &trace.triples, 4);
        let q = AttrValueId::new(e, 0).raw();

        let capped = engine.execute(&QueryRequest::new(q).with_max_depth(2));
        assert!(capped.stats.truncated);
        assert_eq!(capped.stats.bfs_rounds, 2);
        assert_eq!(capped.lineage.triples.len(), 2);

        let by_rows = engine.execute(&QueryRequest::new(q).with_max_triples(3));
        assert!(by_rows.stats.truncated);
        assert_eq!(by_rows.lineage.triples.len(), 3);

        let full = engine.execute(&QueryRequest::new(q));
        assert!(!full.stats.truncated);
        assert_eq!(full.lineage.triples.len(), 6);
    }

    #[test]
    fn rq_deadline_yields_a_prefix_with_a_completeness_bound() {
        use std::time::Duration;
        let e = EntityId(0);
        let triples: Vec<ProvTriple> = (0..6)
            .map(|i| {
                ProvTriple::new(
                    AttrValueId::new(e, i + 1),
                    AttrValueId::new(e, i),
                    OpId(0),
                )
            })
            .collect();
        let trace = Trace::new(triples);
        let engine = RqEngine::new(&sc(), &trace.triples, 4);
        let q = AttrValueId::new(e, 0).raw();

        // A zero deadline is already expired at the first round check: the
        // answer is empty but well-formed, and the bound says so.
        let cut = engine.execute(&QueryRequest::new(q).with_deadline(Duration::ZERO));
        assert!(cut.lineage.is_empty());
        let c = cut.stats.completeness;
        assert!(!c.exhausted);
        assert_eq!(c.rounds_done, 0);
        assert_eq!(c.frontier_remaining, 1);
        // Deadline cuts are reported via the bound, not the cap flag.
        assert!(!cut.stats.truncated);
        assert!(cut.stats.summary().contains("deadline-cut"));

        // The degraded answer is exactly the max_depth=rounds_done prefix.
        let prefix = engine.execute(&QueryRequest::new(q).with_max_depth(c.rounds_done));
        assert_eq!(cut.lineage, prefix.lineage);

        // A generous deadline changes nothing.
        let full = engine.execute(
            &QueryRequest::new(q).with_deadline(Duration::from_secs(3600)),
        );
        assert!(full.stats.completeness.exhausted);
        assert_eq!(full.lineage.triples.len(), 6);
    }
}
