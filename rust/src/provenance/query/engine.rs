//! The engine-agnostic query interface: typed requests, responses carrying
//! per-query cost statistics, and the [`ProvenanceEngine`] trait all three
//! engines (RQ, CCProv, CSProv) implement.
//!
//! The paper's evaluation (Tables 10–12) is really measuring *how much data
//! each engine touches* to answer one lineage query. [`QueryStats`] makes
//! those quantities first-class per query — partitions scanned, rows
//! examined, BFS rounds, driver-vs-cluster path, per-phase wall time — so
//! a router ([`crate::harness::EngineRouter`]) or an operator can compare
//! engines without instrumenting the engine-wide metrics (which interleave
//! under concurrent batched execution).

use super::result::Lineage;
use std::time::Duration;

/// A typed lineage query: the attribute-value to trace plus options.
///
/// Options default to "unbounded, engine defaults":
///
/// * `max_depth` — cap on BFS rounds (lineage depth). When the cap stops
///   the recursion early, [`QueryStats::truncated`] is set. All engines
///   expand level-by-level from the queried item, so a capped lineage is
///   identical across engines.
/// * `max_triples` — best-effort cap on collected lineage triples, checked
///   after each BFS round (a round is never split, so the result may exceed
///   the cap by up to one round's rows).
/// * `tau_override` — per-query override of the engine's τ driver-collect
///   threshold (ignored by RQ, which has no driver path).
///
/// Note: when either cap is set and the recursion runs on the driver, the
/// engines use the built-in level-by-level traversal
/// (`driver_rq::bounded_closure`) instead of the configured
/// [`AncestorClosure`](super::AncestorClosure) backend — the pluggable
/// closures compute full fixpoints and cannot stop at a level boundary. A
/// backend comparison (native vs XLA) must therefore use uncapped requests.
///
/// ```
/// use provspark::provenance::query::QueryRequest;
///
/// let req = QueryRequest::new(42).with_max_depth(3).with_tau(0);
/// assert_eq!(req.item, 42);
/// assert_eq!(req.max_depth, Some(3));
/// assert_eq!(req.tau_override, Some(0));
/// assert_eq!(req.max_triples, None); // unset options keep engine defaults
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueryRequest {
    /// The queried attribute-value (raw id).
    pub item: u64,
    /// Maximum BFS rounds (lineage depth) to expand.
    pub max_depth: Option<u32>,
    /// Best-effort maximum number of lineage triples to collect.
    pub max_triples: Option<usize>,
    /// Per-query τ override (driver-collect threshold).
    pub tau_override: Option<usize>,
}

impl QueryRequest {
    /// An unbounded query for `item`.
    pub fn new(item: u64) -> Self {
        Self { item, ..Default::default() }
    }

    /// Cap the number of BFS rounds.
    pub fn with_max_depth(mut self, depth: u32) -> Self {
        self.max_depth = Some(depth);
        self
    }

    /// Cap (best-effort) the number of collected lineage triples.
    pub fn with_max_triples(mut self, triples: usize) -> Self {
        self.max_triples = Some(triples);
        self
    }

    /// Override the engine's τ driver-collect threshold for this query.
    pub fn with_tau(mut self, tau: usize) -> Self {
        self.tau_override = Some(tau);
        self
    }
}

/// Which execution path answered the recursion phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    /// Volume below τ: collected to the driver and recursed locally.
    Driver,
    /// Recursed as cluster jobs (one multi-lookup job per BFS round).
    Cluster,
}

impl std::fmt::Display for ExecPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecPath::Driver => "driver",
            ExecPath::Cluster => "cluster",
        })
    }
}

/// Per-query cost record: the quantities the paper's evaluation reasons
/// about, attributed to a single request.
///
/// ```
/// use provspark::provenance::query::QueryStats;
///
/// let mut stats = QueryStats::new("csprov");
/// stats.partitions_scanned = 3;
/// stats.rows_examined = 1200;
/// assert!(stats.summary().contains("engine=csprov"));
/// assert!(stats.total_time().is_zero()); // no phases timed yet
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueryStats {
    /// Engine that produced the response (`"rq" | "ccprov" | "csprov"`).
    pub engine: &'static str,
    /// Driver or cluster recursion (RQ is always [`ExecPath::Cluster`]).
    pub path: ExecPath,
    /// Partitions scanned across all phases (resolve, assemble, recurse).
    pub partitions_scanned: u64,
    /// Rows examined by those scans (the paper's data-volume cost).
    pub rows_examined: u64,
    /// Rows moved by shuffles this query triggered (CSProv's re-partition
    /// of the pruned volume on the cluster path).
    pub rows_shuffled: u64,
    /// Rows collected to the driver (driver path only).
    pub rows_collected: u64,
    /// Recursion rounds: distributed BFS rounds on the cluster path, or
    /// levels expanded by the capped driver traversal. 0 only when the
    /// *uncapped* driver closure answered (it computes a fixpoint, not
    /// rounds) or the item was unknown — so this does not discriminate
    /// driver from cluster; use [`QueryStats::path`] for that.
    pub bfs_rounds: u32,
    /// True when `max_depth` / `max_triples` stopped the recursion early.
    pub truncated: bool,
    /// Wall time locating the component / connected set (+ set-lineage).
    pub resolve: Duration,
    /// Wall time assembling the recursion volume (filter / pruned fetch).
    pub assemble: Duration,
    /// Wall time of the recursion itself (cluster BFS or driver closure).
    pub recurse: Duration,
}

impl QueryStats {
    /// Fresh zeroed stats for `engine`.
    pub fn new(engine: &'static str) -> Self {
        Self {
            engine,
            path: ExecPath::Driver,
            partitions_scanned: 0,
            rows_examined: 0,
            rows_shuffled: 0,
            rows_collected: 0,
            bfs_rounds: 0,
            truncated: false,
            resolve: Duration::ZERO,
            assemble: Duration::ZERO,
            recurse: Duration::ZERO,
        }
    }

    /// Total wall time across the recorded phases.
    pub fn total_time(&self) -> Duration {
        self.resolve + self.assemble + self.recurse
    }

    /// One-line rendering for CLI / bench output.
    pub fn summary(&self) -> String {
        use crate::util::fmt::{human_count, human_duration};
        format!(
            "engine={} path={} parts_scanned={} rows_examined={} shuffled={} collected={} \
             rounds={}{} resolve={} assemble={} recurse={}",
            self.engine,
            self.path,
            self.partitions_scanned,
            human_count(self.rows_examined),
            human_count(self.rows_shuffled),
            human_count(self.rows_collected),
            self.bfs_rounds,
            if self.truncated { " truncated" } else { "" },
            human_duration(self.resolve),
            human_duration(self.assemble),
            human_duration(self.recurse),
        )
    }
}

/// A lineage plus the cost of computing it.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    pub lineage: Lineage,
    pub stats: QueryStats,
}

/// The uniform query interface over RQ / CCProv / CSProv.
///
/// All engines answer any [`QueryRequest`] with an identical [`Lineage`]
/// (the cross-engine equivalence property test drives them through
/// `&dyn ProvenanceEngine`); they differ only in the [`QueryStats`] cost of
/// getting there.
///
/// ```
/// use provspark::config::ClusterConfig;
/// use provspark::minispark::MiniSpark;
/// use provspark::provenance::model::ProvTriple;
/// use provspark::provenance::query::{ProvenanceEngine, QueryRequest, RqEngine};
/// use provspark::util::ids::{AttrValueId, EntityId, OpId};
///
/// // One derivation step: b ← a.
/// let a = AttrValueId::new(EntityId(0), 1);
/// let b = AttrValueId::new(EntityId(1), 1);
/// let triples = vec![ProvTriple::new(a, b, OpId(0))];
/// let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() });
///
/// // Any engine — here the RQ baseline — serves the same interface.
/// let engine: &dyn ProvenanceEngine = &RqEngine::new(&sc, &triples, 4);
/// let resp = engine.execute(&QueryRequest::new(b.raw()));
/// assert_eq!(resp.lineage.ancestors, vec![a.raw()]);
/// assert_eq!(resp.stats.engine, "rq");
/// assert!(engine.query(a.raw()).is_empty()); // inputs have no lineage
/// ```
pub trait ProvenanceEngine: Send + Sync {
    /// Short stable engine name (`"rq" | "ccprov" | "csprov"`).
    fn name(&self) -> &'static str;

    /// Answer one typed query.
    fn execute(&self, req: &QueryRequest) -> QueryResponse;

    /// Convenience: unbounded lineage of `item`, discarding the stats.
    fn query(&self, item: u64) -> Lineage {
        self.execute(&QueryRequest::new(item)).lineage
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_sets_options() {
        let r = QueryRequest::new(7).with_max_depth(3).with_max_triples(100).with_tau(0);
        assert_eq!(r.item, 7);
        assert_eq!(r.max_depth, Some(3));
        assert_eq!(r.max_triples, Some(100));
        assert_eq!(r.tau_override, Some(0));
        let d = QueryRequest::new(7);
        assert_eq!(d.max_depth, None);
        assert_eq!(d.tau_override, None);
    }

    #[test]
    fn stats_summary_and_total() {
        let mut s = QueryStats::new("csprov");
        s.path = ExecPath::Cluster;
        s.partitions_scanned = 3;
        s.rows_examined = 1200;
        s.bfs_rounds = 4;
        s.resolve = Duration::from_millis(2);
        s.recurse = Duration::from_millis(5);
        assert_eq!(s.total_time(), Duration::from_millis(7));
        let line = s.summary();
        assert!(line.contains("engine=csprov"));
        assert!(line.contains("path=cluster"));
        assert!(line.contains("rounds=4"));
        assert!(!line.contains("truncated"));
        s.truncated = true;
        assert!(s.summary().contains("truncated"));
    }
}
