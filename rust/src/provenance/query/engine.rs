//! The engine-agnostic query interface: typed requests, responses carrying
//! per-query cost statistics, and the [`ProvenanceEngine`] trait all three
//! engines (RQ, CCProv, CSProv) implement.
//!
//! The paper's evaluation (Tables 10–12) is really measuring *how much data
//! each engine touches* to answer one lineage query. [`QueryStats`] makes
//! those quantities first-class per query — partitions scanned, rows
//! examined, BFS rounds, driver-vs-cluster path, per-phase wall time — so
//! a router ([`crate::harness::EngineRouter`]) or an operator can compare
//! engines without instrumenting the engine-wide metrics (which interleave
//! under concurrent batched execution).

use super::result::Lineage;
use std::time::Duration;

/// A typed lineage query: the attribute-value to trace plus options.
///
/// Options default to "unbounded, engine defaults":
///
/// * `max_depth` — cap on BFS rounds (lineage depth). When the cap stops
///   the recursion early, [`QueryStats::truncated`] is set. All engines
///   expand level-by-level from the queried item, so a capped lineage is
///   identical across engines.
/// * `max_triples` — best-effort cap on collected lineage triples, checked
///   after each BFS round (a round is never split, so the result may exceed
///   the cap by up to one round's rows).
/// * `tau_override` — per-query override of the engine's τ driver-collect
///   threshold (ignored by RQ, which has no driver path).
/// * `deadline` — wall-time budget. The BFS loops check it at every round
///   boundary and return the partial lineage built so far plus a
///   [`Completeness`] bound instead of an error; the partial answer is
///   always a *prefix* of the full lineage (identical to a `max_depth`
///   query at the round where time ran out).
/// * `retries` — how many times the harness re-runs this query after an
///   execution failure (a task that exhausted its in-job retry budget)
///   before reporting [`QueryOutcome::Failed`].
///
/// Note: when a cap or deadline is set and the recursion runs on the
/// driver, the engines use the built-in level-by-level traversal
/// (`driver_rq::bounded_closure`) instead of the configured
/// [`AncestorClosure`](super::AncestorClosure) backend — the pluggable
/// closures compute full fixpoints and cannot stop at a level boundary. A
/// backend comparison (native vs XLA) must therefore use uncapped requests.
///
/// ```
/// use provspark::provenance::query::QueryRequest;
/// use std::time::Duration;
///
/// let req = QueryRequest::new(42).with_max_depth(3).with_tau(0);
/// assert_eq!(req.item, 42);
/// assert_eq!(req.max_depth, Some(3));
/// assert_eq!(req.tau_override, Some(0));
/// assert_eq!(req.max_triples, None); // unset options keep engine defaults
///
/// let bounded = QueryRequest::new(42)
///     .with_deadline(Duration::from_millis(50))
///     .with_retries(2);
/// assert_eq!(bounded.deadline, Some(Duration::from_millis(50)));
/// assert_eq!(bounded.retries, 2);
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueryRequest {
    /// The queried attribute-value (raw id).
    pub item: u64,
    /// Maximum BFS rounds (lineage depth) to expand.
    pub max_depth: Option<u32>,
    /// Best-effort maximum number of lineage triples to collect.
    pub max_triples: Option<usize>,
    /// Per-query τ override (driver-collect threshold).
    pub tau_override: Option<usize>,
    /// Wall-time budget: stop at the first BFS round boundary past it and
    /// return the partial answer with its [`Completeness`] bound.
    pub deadline: Option<Duration>,
    /// Whole-query retry budget on execution failure (harness-level; on
    /// top of the per-task retries inside each job).
    pub retries: u32,
}

impl QueryRequest {
    /// An unbounded query for `item`.
    pub fn new(item: u64) -> Self {
        Self { item, ..Default::default() }
    }

    /// Cap the number of BFS rounds.
    pub fn with_max_depth(mut self, depth: u32) -> Self {
        self.max_depth = Some(depth);
        self
    }

    /// Cap (best-effort) the number of collected lineage triples.
    pub fn with_max_triples(mut self, triples: usize) -> Self {
        self.max_triples = Some(triples);
        self
    }

    /// Override the engine's τ driver-collect threshold for this query.
    pub fn with_tau(mut self, tau: usize) -> Self {
        self.tau_override = Some(tau);
        self
    }

    /// Bound the query's wall time; past it, a partial (prefix) lineage
    /// and its [`Completeness`] come back instead of an error.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Re-run the whole query up to `retries` times on execution failure.
    pub fn with_retries(mut self, retries: u32) -> Self {
        self.retries = retries;
        self
    }
}

/// How much of the full answer a (possibly degraded) response covers.
///
/// The default is the *complete* bound — engines only report otherwise
/// when a deadline stopped the recursion with work left:
/// `rounds_done` BFS rounds were fully expanded, `frontier_remaining`
/// items were still waiting at the cut, and `exhausted` says whether the
/// traversal ran to its natural fixpoint. Because every engine expands
/// level-by-level, a deadline cut at round *k* returns exactly the lineage
/// a `max_depth = k` query would — the partial answer is a well-defined
/// prefix, not an arbitrary subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completeness {
    /// BFS rounds fully expanded before the cut.
    pub rounds_done: u32,
    /// Frontier items not yet expanded when the deadline hit (0 when
    /// `exhausted`).
    pub frontier_remaining: usize,
    /// True when the recursion reached its fixpoint (no deadline cut).
    pub exhausted: bool,
}

impl Default for Completeness {
    fn default() -> Self {
        Self { rounds_done: 0, frontier_remaining: 0, exhausted: true }
    }
}

/// Per-request disposition in a batch report: did the query answer in
/// full, degrade (deadline/cap cut), or fail outright after retries?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryOutcome {
    /// Complete answer.
    Full,
    /// Partial answer: a request cap or the deadline stopped the
    /// recursion early; the lineage is a prefix of the full one.
    Partial,
    /// Execution failed even after the request's retry budget; the
    /// response carries an empty lineage.
    Failed,
}

impl QueryOutcome {
    /// Classify a response from its stats (the supervisor reports
    /// [`QueryOutcome::Failed`] directly, never via stats).
    pub fn of(stats: &QueryStats) -> Self {
        if stats.truncated || !stats.completeness.exhausted {
            QueryOutcome::Partial
        } else {
            QueryOutcome::Full
        }
    }
}

impl std::fmt::Display for QueryOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QueryOutcome::Full => "full",
            QueryOutcome::Partial => "partial",
            QueryOutcome::Failed => "failed",
        })
    }
}

/// Which execution path answered the recursion phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    /// Volume below τ: collected to the driver and recursed locally.
    Driver,
    /// Recursed as cluster jobs (one multi-lookup job per BFS round).
    Cluster,
}

impl std::fmt::Display for ExecPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecPath::Driver => "driver",
            ExecPath::Cluster => "cluster",
        })
    }
}

/// Per-query cost record: the quantities the paper's evaluation reasons
/// about, attributed to a single request.
///
/// ```
/// use provspark::provenance::query::QueryStats;
///
/// let mut stats = QueryStats::new("csprov");
/// stats.partitions_scanned = 3;
/// stats.rows_examined = 1200;
/// assert!(stats.summary().contains("engine=csprov"));
/// assert!(stats.total_time().is_zero()); // no phases timed yet
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueryStats {
    /// Engine that produced the response (`"rq" | "ccprov" | "csprov"`).
    pub engine: &'static str,
    /// Driver or cluster recursion (RQ is always [`ExecPath::Cluster`]).
    pub path: ExecPath,
    /// Partitions scanned across all phases (resolve, assemble, recurse).
    pub partitions_scanned: u64,
    /// Rows examined by those scans (the paper's data-volume cost).
    pub rows_examined: u64,
    /// Rows moved by shuffles this query triggered (CSProv's re-partition
    /// of the pruned volume on the cluster path).
    pub rows_shuffled: u64,
    /// Rows collected to the driver (driver path only).
    pub rows_collected: u64,
    /// Partition fetches this query served warm from the partition cache
    /// (spilled engines only; always 0 when fully resident).
    pub cache_hits: u64,
    /// Segments this query paged in from disk — the out-of-core cost the
    /// byte budget trades for memory.
    pub cache_misses: u64,
    /// Fused stages the lazy planner ran (or replayed from a memoized
    /// plan) for this query; 0 on purely eager paths.
    pub stages_run: u64,
    /// Logical ops folded into those stages beyond the first of each.
    pub ops_fused: u64,
    /// Intermediate rows stage fusion never materialized for this query.
    pub intermediates_avoided: u64,
    /// Recursion rounds: distributed BFS rounds on the cluster path, or
    /// levels expanded by the capped driver traversal. 0 only when the
    /// *uncapped* driver closure answered (it computes a fixpoint, not
    /// rounds) or the item was unknown — so this does not discriminate
    /// driver from cluster; use [`QueryStats::path`] for that.
    pub bfs_rounds: u32,
    /// True when `max_depth` / `max_triples` stopped the recursion early.
    pub truncated: bool,
    /// True when the serving front answered from its result cache: no
    /// engine ran, so every scan counter above is zero. Engines never set
    /// this; only `serve::ServeFront` does.
    pub served_from_cache: bool,
    /// Deadline bound: how much of the full traversal this answer covers
    /// (the complete bound unless a deadline cut the recursion).
    pub completeness: Completeness,
    /// Wall time locating the component / connected set (+ set-lineage).
    pub resolve: Duration,
    /// Wall time assembling the recursion volume (filter / pruned fetch).
    pub assemble: Duration,
    /// Wall time of the recursion itself (cluster BFS or driver closure).
    pub recurse: Duration,
}

impl QueryStats {
    /// Fresh zeroed stats for `engine`.
    pub fn new(engine: &'static str) -> Self {
        Self {
            engine,
            path: ExecPath::Driver,
            partitions_scanned: 0,
            rows_examined: 0,
            rows_shuffled: 0,
            rows_collected: 0,
            cache_hits: 0,
            cache_misses: 0,
            stages_run: 0,
            ops_fused: 0,
            intermediates_avoided: 0,
            bfs_rounds: 0,
            truncated: false,
            served_from_cache: false,
            completeness: Completeness::default(),
            resolve: Duration::ZERO,
            assemble: Duration::ZERO,
            recurse: Duration::ZERO,
        }
    }

    /// Total wall time across the recorded phases.
    pub fn total_time(&self) -> Duration {
        self.resolve + self.assemble + self.recurse
    }

    /// One-line rendering for CLI / bench output.
    pub fn summary(&self) -> String {
        use crate::util::fmt::{human_count, human_duration};
        let deadline_cut = if self.completeness.exhausted {
            String::new()
        } else {
            format!(
                " deadline-cut(rounds_done={} frontier={})",
                self.completeness.rounds_done, self.completeness.frontier_remaining
            )
        };
        let paging = if self.cache_hits == 0 && self.cache_misses == 0 {
            String::new()
        } else {
            format!(" cache_hits={} cache_misses={}", self.cache_hits, self.cache_misses)
        };
        let stages = if self.stages_run == 0 {
            String::new()
        } else {
            format!(
                " stages={} fused={} intermediates_avoided={}",
                self.stages_run,
                self.ops_fused,
                human_count(self.intermediates_avoided)
            )
        };
        format!(
            "engine={} path={} parts_scanned={} rows_examined={} shuffled={} collected={}{}{} \
             rounds={}{}{}{} resolve={} assemble={} recurse={}",
            self.engine,
            self.path,
            self.partitions_scanned,
            human_count(self.rows_examined),
            human_count(self.rows_shuffled),
            human_count(self.rows_collected),
            paging,
            stages,
            self.bfs_rounds,
            if self.truncated { " truncated" } else { "" },
            if self.served_from_cache { " served_from_cache" } else { "" },
            deadline_cut,
            human_duration(self.resolve),
            human_duration(self.assemble),
            human_duration(self.recurse),
        )
    }
}

/// A lineage plus the cost of computing it.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    pub lineage: Lineage,
    pub stats: QueryStats,
}

/// The uniform query interface over RQ / CCProv / CSProv.
///
/// All engines answer any [`QueryRequest`] with an identical [`Lineage`]
/// (the cross-engine equivalence property test drives them through
/// `&dyn ProvenanceEngine`); they differ only in the [`QueryStats`] cost of
/// getting there.
///
/// ```
/// use provspark::config::ClusterConfig;
/// use provspark::minispark::MiniSpark;
/// use provspark::provenance::model::ProvTriple;
/// use provspark::provenance::query::{ProvenanceEngine, QueryRequest, RqEngine};
/// use provspark::util::ids::{AttrValueId, EntityId, OpId};
///
/// // One derivation step: b ← a.
/// let a = AttrValueId::new(EntityId(0), 1);
/// let b = AttrValueId::new(EntityId(1), 1);
/// let triples = vec![ProvTriple::new(a, b, OpId(0))];
/// let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() });
///
/// // Any engine — here the RQ baseline — serves the same interface.
/// let engine: &dyn ProvenanceEngine = &RqEngine::new(&sc, &triples, 4);
/// let resp = engine.execute(&QueryRequest::new(b.raw()));
/// assert_eq!(resp.lineage.ancestors, vec![a.raw()]);
/// assert_eq!(resp.stats.engine, "rq");
/// assert!(engine.query(a.raw()).is_empty()); // inputs have no lineage
/// ```
pub trait ProvenanceEngine: Send + Sync {
    /// Short stable engine name (`"rq" | "ccprov" | "csprov"`).
    fn name(&self) -> &'static str;

    /// Answer one typed query.
    fn execute(&self, req: &QueryRequest) -> QueryResponse;

    /// Convenience: unbounded lineage of `item`, discarding the stats.
    fn query(&self, item: u64) -> Lineage {
        self.execute(&QueryRequest::new(item)).lineage
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_sets_options() {
        let r = QueryRequest::new(7).with_max_depth(3).with_max_triples(100).with_tau(0);
        assert_eq!(r.item, 7);
        assert_eq!(r.max_depth, Some(3));
        assert_eq!(r.max_triples, Some(100));
        assert_eq!(r.tau_override, Some(0));
        let d = QueryRequest::new(7);
        assert_eq!(d.max_depth, None);
        assert_eq!(d.tau_override, None);
    }

    #[test]
    fn stats_summary_and_total() {
        let mut s = QueryStats::new("csprov");
        s.path = ExecPath::Cluster;
        s.partitions_scanned = 3;
        s.rows_examined = 1200;
        s.bfs_rounds = 4;
        s.resolve = Duration::from_millis(2);
        s.recurse = Duration::from_millis(5);
        assert_eq!(s.total_time(), Duration::from_millis(7));
        let line = s.summary();
        assert!(line.contains("engine=csprov"));
        assert!(line.contains("path=cluster"));
        assert!(line.contains("rounds=4"));
        assert!(!line.contains("truncated"));
        s.truncated = true;
        assert!(s.summary().contains("truncated"));
    }

    #[test]
    fn completeness_default_is_the_complete_bound() {
        let c = Completeness::default();
        assert!(c.exhausted);
        assert_eq!(c.rounds_done, 0);
        assert_eq!(c.frontier_remaining, 0);
    }

    #[test]
    fn outcome_classification_and_summary_marker() {
        let mut s = QueryStats::new("rq");
        assert_eq!(QueryOutcome::of(&s), QueryOutcome::Full);
        s.truncated = true;
        assert_eq!(QueryOutcome::of(&s), QueryOutcome::Partial);
        s.truncated = false;
        s.completeness = Completeness { rounds_done: 2, frontier_remaining: 7, exhausted: false };
        assert_eq!(QueryOutcome::of(&s), QueryOutcome::Partial);
        let line = s.summary();
        assert!(line.contains("deadline-cut(rounds_done=2 frontier=7)"), "{line}");
        assert_eq!(QueryOutcome::Failed.to_string(), "failed");
    }
}
