//! On-disk persistence for traces and preprocessed provenance.
//!
//! The paper stores provenance on HDFS and pre-computes components/sets
//! once; we persist the same artifacts locally in a simple length-prefixed
//! little-endian binary format (with a CSV export for inspection).
//!
//! Preprocessed files are written in the **v2** layout (`PSPKPRE2`), whose
//! header records the incremental-epoch fields — θ, the big-set bound, and
//! the epoch counter — so a persisted index can keep absorbing
//! [`TripleBatch`](crate::provenance::incremental::TripleBatch) deltas
//! after a reload (the CLI `ingest` subcommand round-trips through here).
//! v1 files (`PSPKPRE1`, pre-epoch) still load, with those fields zeroed —
//! such an index answers queries but refuses ingestion until re-preprocessed.

use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::util::ids::{AttrValueId, ComponentId, OpId, SetId};
use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC_TRACE: &[u8; 8] = b"PSPKTRC1";
const MAGIC_PRE_V1: &[u8; 8] = b"PSPKPRE1";
const MAGIC_PRE: &[u8; 8] = b"PSPKPRE2";

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn w_triple(w: &mut impl Write, t: &ProvTriple) -> Result<()> {
    w_u64(w, t.src.raw())?;
    w_u64(w, t.dst.raw())?;
    w_u32(w, t.op.0)
}

fn r_triple(r: &mut impl Read) -> Result<ProvTriple> {
    Ok(ProvTriple::new(
        AttrValueId(r_u64(r)?),
        AttrValueId(r_u64(r)?),
        OpId(r_u32(r)?),
    ))
}

/// Save a raw trace.
pub fn save_trace(path: &Path, trace: &Trace) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_TRACE)?;
    w_u64(&mut w, trace.triples.len() as u64)?;
    for t in &trace.triples {
        w_triple(&mut w, t)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a raw trace. Errors name the offending path.
pub fn load_trace(path: &Path) -> Result<Trace> {
    load_trace_inner(path).with_context(|| format!("loading trace file {path:?}"))
}

fn load_trace_inner(path: &Path) -> Result<Trace> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC_TRACE {
        bail!("not a provspark trace file (bad magic)");
    }
    let n = r_u64(&mut r)? as usize;
    let mut triples = Vec::with_capacity(n);
    for _ in 0..n {
        triples.push(r_triple(&mut r)?);
    }
    Ok(Trace::new(triples))
}

/// Save preprocessed provenance (everything the query engines need),
/// including the incremental-epoch header (θ / big-set bound / epoch).
pub fn save_preprocessed(path: &Path, pre: &Preprocessed) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_PRE)?;
    // v2 header: the fields incremental ingestion needs to keep going.
    w_u64(&mut w, pre.theta as u64)?;
    w_u64(&mut w, pre.big_threshold as u64)?;
    w_u64(&mut w, pre.epoch)?;

    w_u64(&mut w, pre.cc_triples.len() as u64)?;
    for t in &pre.cc_triples {
        w_triple(&mut w, &t.triple)?;
        w_u64(&mut w, t.ccid.0)?;
    }
    w_u64(&mut w, pre.cs_triples.len() as u64)?;
    for t in &pre.cs_triples {
        w_triple(&mut w, &t.triple)?;
        w_u64(&mut w, t.src_csid.0)?;
        w_u64(&mut w, t.dst_csid.0)?;
    }
    w_u64(&mut w, pre.set_deps.len() as u64)?;
    for d in &pre.set_deps {
        w_u64(&mut w, d.src_csid.0)?;
        w_u64(&mut w, d.dst_csid.0)?;
    }
    w_u64(&mut w, pre.cc_of.len() as u64)?;
    for (&n, &c) in &pre.cc_of {
        w_u64(&mut w, n)?;
        w_u64(&mut w, c)?;
    }
    w_u64(&mut w, pre.cs_of.len() as u64)?;
    for (&n, &c) in &pre.cs_of {
        w_u64(&mut w, n)?;
        w_u64(&mut w, c)?;
    }
    w_u64(&mut w, pre.large_components.len() as u64)?;
    for &(cc, nodes, edges) in &pre.large_components {
        w_u64(&mut w, cc)?;
        w_u64(&mut w, nodes as u64)?;
        w_u64(&mut w, edges as u64)?;
    }
    w_u64(&mut w, pre.component_count as u64)?;
    w_u64(&mut w, pre.set_count as u64)?;
    w.flush()?;
    Ok(())
}

/// Load preprocessed provenance. Pass-stats and timings are not persisted
/// (they are preprocessing-run artifacts, reported at preprocessing time).
/// Accepts v2 (`PSPKPRE2`) and legacy v1 (`PSPKPRE1`, epoch fields zeroed)
/// files; errors name the offending path.
pub fn load_preprocessed(path: &Path) -> Result<Preprocessed> {
    load_preprocessed_inner(path)
        .with_context(|| format!("loading preprocessed file {path:?}"))
}

fn load_preprocessed_inner(path: &Path) -> Result<Preprocessed> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC_PRE && &magic != MAGIC_PRE_V1 {
        bail!("not a provspark preprocessed file (bad magic)");
    }
    let mut pre = Preprocessed::default();
    if &magic == MAGIC_PRE {
        pre.theta = r_u64(&mut r).context("read theta")? as usize;
        pre.big_threshold = r_u64(&mut r).context("read big_threshold")? as usize;
        pre.epoch = r_u64(&mut r).context("read epoch")?;
    }

    let n = r_u64(&mut r)? as usize;
    pre.cc_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cc_triples.push(CcTriple { triple, ccid: ComponentId(r_u64(&mut r)?) });
    }
    let n = r_u64(&mut r)? as usize;
    pre.cs_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cs_triples.push(CsTriple {
            triple,
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r)? as usize;
    for _ in 0..n {
        pre.set_deps.push(SetDep {
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r)? as usize;
    pre.cc_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cc_of.insert(k, v);
    }
    let n = r_u64(&mut r)? as usize;
    pre.cs_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cs_of.insert(k, v);
    }
    let n = r_u64(&mut r)? as usize;
    for _ in 0..n {
        let cc = r_u64(&mut r)?;
        let nodes = r_u64(&mut r)? as usize;
        let edges = r_u64(&mut r)? as usize;
        pre.large_components.push((cc, nodes, edges));
    }
    pre.component_count = r_u64(&mut r)? as usize;
    pre.set_count = r_u64(&mut r)? as usize;
    Ok(pre)
}

/// [`save_trace`] through a temp file + atomic rename: an interrupted
/// write never destroys an existing file at `path`. This is what the CLI
/// `ingest` subcommand persists with — it updates its own inputs in place,
/// so a mid-write crash must not lose the only copy of the index.
pub fn save_trace_atomic(path: &Path, trace: &Trace) -> Result<()> {
    save_atomic(path, |tmp| save_trace(tmp, trace))
}

/// [`save_preprocessed`] through a temp file + atomic rename (see
/// [`save_trace_atomic`]).
pub fn save_preprocessed_atomic(path: &Path, pre: &Preprocessed) -> Result<()> {
    save_atomic(path, |tmp| save_preprocessed(tmp, pre))
}

fn save_atomic(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    write(&tmp)?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("moving {tmp:?} into place at {path:?}"))?;
    Ok(())
}

/// CSV export of a trace (`src,dst,op`) for external inspection.
pub fn export_csv(path: &Path, trace: &Trace) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "src,dst,op")?;
    for t in &trace.triples {
        writeln!(w, "{},{},{}", t.src.raw(), t.dst.raw(), t.op.0)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("provspark_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn trace_roundtrip() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let p = tmp("trace.bin");
        save_trace(&p, &trace).unwrap();
        let loaded = load_trace(&p).unwrap();
        assert_eq!(trace.triples, loaded.triples);
    }

    #[test]
    fn preprocessed_roundtrip() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let p = tmp("pre.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(pre.cc_triples, loaded.cc_triples);
        assert_eq!(pre.cs_triples, loaded.cs_triples);
        assert_eq!(pre.set_deps, loaded.set_deps);
        assert_eq!(pre.cc_of, loaded.cc_of);
        assert_eq!(pre.cs_of, loaded.cs_of);
        assert_eq!(pre.large_components, loaded.large_components);
        assert_eq!(pre.component_count, loaded.component_count);
        assert_eq!(pre.set_count, loaded.set_count);
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("bogus.bin");
        std::fs::write(&p, b"NOTMAGIC123").unwrap();
        assert!(load_trace(&p).is_err());
        assert!(load_preprocessed(&p).is_err());
    }

    #[test]
    fn atomic_save_replaces_and_leaves_no_temp() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let tp = tmp("atomic_trace.bin");
        let pp = tmp("atomic_pre.bin");
        // Seed the destination with garbage an interrupted write must not
        // be able to leave behind.
        std::fs::write(&tp, b"GARBAGE").unwrap();
        save_trace_atomic(&tp, &trace).unwrap();
        save_preprocessed_atomic(&pp, &pre).unwrap();
        assert_eq!(load_trace(&tp).unwrap().triples, trace.triples);
        assert_eq!(load_preprocessed(&pp).unwrap().epoch, pre.epoch);
        for p in [&tp, &pp] {
            let mut t = p.as_os_str().to_owned();
            t.push(".tmp");
            assert!(!std::path::PathBuf::from(t).exists(), "temp file left behind");
        }
    }

    #[test]
    fn roundtrip_preserves_incremental_epoch_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 7; // as if 7 batches were ingested
        assert_eq!(pre.theta, 200);
        let p = tmp("pre_epoch.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 200);
        assert_eq!(loaded.big_threshold, 100);
        assert_eq!(loaded.epoch, 7);
        // …alongside everything the query engines need.
        assert_eq!(pre.cc_triples, loaded.cc_triples);
        assert_eq!(pre.cs_of, loaded.cs_of);
    }

    #[test]
    fn legacy_v1_file_loads_with_zeroed_epoch_fields() {
        // A minimal empty v1 file: old magic + the 8 zero section counts
        // (cc, cs, deps, cc_of, cs_of, large, component_count, set_count).
        let p = tmp("pre_v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE1");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        std::fs::write(&p, bytes).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 0, "v1 has no recorded θ");
        assert_eq!(loaded.epoch, 0);
        assert!(loaded.cc_triples.is_empty());
    }

    #[test]
    fn load_errors_name_the_offending_path() {
        let missing = tmp("definitely_missing.bin");
        let _ = std::fs::remove_file(&missing);
        for err in [
            format!("{:#}", load_trace(&missing).unwrap_err()),
            format!("{:#}", load_preprocessed(&missing).unwrap_err()),
        ] {
            assert!(
                err.contains("definitely_missing.bin"),
                "error must name the path: {err}"
            );
        }
        // Truncated file: magic only, sections missing.
        let p = tmp("truncated.bin");
        std::fs::write(&p, b"PSPKPRE2").unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(err.contains("truncated.bin"), "error must name the path: {err}");
    }

    #[test]
    fn csv_export_has_header_and_rows() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let p = tmp("trace.csv");
        export_csv(&p, &trace).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("src,dst,op\n"));
        assert_eq!(text.lines().count(), trace.len() + 1);
    }
}
