//! On-disk persistence for traces and preprocessed provenance.
//!
//! The paper stores provenance on HDFS and pre-computes components/sets
//! once; we persist the same artifacts locally in a simple length-prefixed
//! little-endian binary format (with a CSV export for inspection).
//!
//! Preprocessed files are written in the **v5** layout (`PSPKPRE5`): the
//! v3 header — the incremental-epoch fields (θ, the big-set bound, the
//! epoch counter), the workflow fingerprint
//! ([`crate::workflow::workflow_fingerprint`], so a reloaded index can
//! refuse ingestion under a mismatched workflow) and the component-space
//! shard assignment (`shard_index`/`shard_count`, 0/0 = unsharded — see
//! [`crate::provenance::shard`]) — followed by a **per-partition
//! directory**. The cc/cs triple sections are split into hash-partitioned
//! segments keyed exactly as the query engines partition them, so
//! [`SegmentedPre`] serves any single partition with one seek: the
//! out-of-core tier ([`crate::storage`]) can open a preprocessed index
//! without deserializing the whole file. v5 stores every section as a
//! delta+varint **compressed columnar block**
//! ([`crate::storage::compress_columnar`]) with rows sorted within each
//! partition, trading decode CPU for the disk bytes that dominate demand
//! paging; the directory carries `(offset, rows, bytes)` per section so
//! readers size one exact read.
//!
//! Older files still load, with missing header fields zeroed: v4
//! (`PSPKPRE4`, segmented but uncompressed — still writable via
//! [`save_preprocessed_v4`]), v3 (`PSPKPRE3`, monolithic sections), v2
//! (`PSPKPRE2`, pre-fingerprint — ingests without workflow validation)
//! and v1 (`PSPKPRE1`, pre-epoch — answers queries but refuses ingestion
//! until re-preprocessed).

use crate::fault::{io_probe, FaultSite};
use crate::minispark::HashPartitioner;
use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::storage::{compress_columnar, decompress_columnar, ColumnarCodec, SegmentCodec};
use crate::util::ids::{AttrValueId, ComponentId, OpId, SetId};
use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const MAGIC_TRACE: &[u8; 8] = b"PSPKTRC1";
const MAGIC_PRE_V1: &[u8; 8] = b"PSPKPRE1";
const MAGIC_PRE_V2: &[u8; 8] = b"PSPKPRE2";
const MAGIC_PRE_V3: &[u8; 8] = b"PSPKPRE3";
const MAGIC_PRE_V4: &[u8; 8] = b"PSPKPRE4";
const MAGIC_PRE_V5: &[u8; 8] = b"PSPKPRE5";

/// v4/v5 fixed prefix: magic + 9 `u64` header fields (θ, big-set bound,
/// epoch, workflow fingerprint, shard index, shard count, component
/// count, set count, partition count). The directory follows — `(offset,
/// rows)` pairs in v4, `(offset, rows, bytes)` triples in v5 (compressed
/// block sizes are not derivable from row counts).
const V4_HEADER_BYTES: usize = 8 + 9 * 8;

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Validate an on-disk record count against the file's actual size before
/// any allocation sized by it. A flipped bit (or a file truncated mid-
/// header) can make a count field claim, say, `u64::MAX` records; feeding
/// that into `Vec::with_capacity` aborts the process on allocation failure
/// instead of returning an error. `record_bytes` is the fixed on-disk size
/// of one record, so `n` records can never be genuine unless
/// `n * record_bytes` fits in the file.
fn checked_count(n: u64, record_bytes: u64, file_len: u64, what: &str) -> Result<usize> {
    match n.checked_mul(record_bytes) {
        Some(bytes) if bytes <= file_len => Ok(n as usize),
        _ => bail!(
            "{what} count {n} is implausible for a {file_len}-byte file \
             ({record_bytes} bytes per record): corrupt or truncated header"
        ),
    }
}

fn w_triple(w: &mut impl Write, t: &ProvTriple) -> Result<()> {
    w_u64(w, t.src.raw())?;
    w_u64(w, t.dst.raw())?;
    w_u32(w, t.op.0)
}

fn r_triple(r: &mut impl Read) -> Result<ProvTriple> {
    Ok(ProvTriple::new(
        AttrValueId(r_u64(r)?),
        AttrValueId(r_u64(r)?),
        OpId(r_u32(r)?),
    ))
}

/// Save a raw trace.
pub fn save_trace(path: &Path, trace: &Trace) -> Result<()> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_TRACE)?;
    w_u64(&mut w, trace.triples.len() as u64)?;
    for t in &trace.triples {
        w_triple(&mut w, t)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a raw trace. Errors name the offending path.
pub fn load_trace(path: &Path) -> Result<Trace> {
    load_trace_inner(path).with_context(|| format!("loading trace file {path:?}"))
}

fn load_trace_inner(path: &Path) -> Result<Trace> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC_TRACE {
        bail!("not a provspark trace file (bad magic)");
    }
    let n = r_u64(&mut r).context("read triple count")?;
    let n = checked_count(n, 20, file_len, "triple")?;
    let mut triples = Vec::with_capacity(n);
    for _ in 0..n {
        triples.push(r_triple(&mut r)?);
    }
    Ok(Trace::new(triples))
}

/// Number of hash partitions [`save_preprocessed`] splits the cc/cs
/// triple sections into. Matches the engines' default dataset
/// partitioning, so a v4 segment maps one-to-one onto an engine
/// partition.
pub const DEFAULT_PRE_PARTITIONS: usize = 64;

/// Save preprocessed provenance (everything the query engines need),
/// including the incremental-epoch header (θ / big-set bound / epoch),
/// the workflow fingerprint and the shard assignment. Writes the
/// compressed segmented **v5** layout with [`DEFAULT_PRE_PARTITIONS`]
/// partitions — see [`save_preprocessed_with_partitions`].
pub fn save_preprocessed(path: &Path, pre: &Preprocessed) -> Result<()> {
    save_preprocessed_with_partitions(path, pre, DEFAULT_PRE_PARTITIONS)
}

/// Hash-split the cc/cs triple sections exactly as the query engines
/// partition their datasets — cc keyed by `dst`, cs keyed by `dst_csid`,
/// through the same [`HashPartitioner`] — so segment *i* holds exactly
/// the rows engine partition *i* would.
fn partition_triples(
    pre: &Preprocessed,
    np: usize,
) -> (Vec<Vec<CcTriple>>, Vec<Vec<CsTriple>>) {
    let parter = HashPartitioner::new(np);
    let mut cc: Vec<Vec<CcTriple>> = vec![Vec::new(); np];
    for t in &pre.cc_triples {
        cc[parter.partition_of(t.triple.dst.raw())].push(*t);
    }
    let mut cs: Vec<Vec<CsTriple>> = vec![Vec::new(); np];
    for t in &pre.cs_triples {
        cs[parter.partition_of(t.dst_csid.0)].push(*t);
    }
    (cc, cs)
}

/// Save preprocessed provenance as a **v5** (`PSPKPRE5`) compressed
/// segmented file.
///
/// The cc/cs triple sections are split into `num_partitions` segments
/// keyed as the engines key them (see [`partition_triples`]); every
/// section is written as a delta+varint columnar block
/// ([`crate::storage::compress_columnar`]), with triple rows sorted
/// within their partition so the deltas stay small. A directory of
/// absolute `(offset, rows, bytes)` triples precedes the payload;
/// [`SegmentedPre`] serves any one section with a single sized read, and
/// [`load_preprocessed`] reassembles the whole index.
pub fn save_preprocessed_with_partitions(
    path: &Path,
    pre: &Preprocessed,
    num_partitions: usize,
) -> Result<()> {
    save_preprocessed_v5_inner(path, pre, num_partitions)
        .with_context(|| format!("writing preprocessed file {path:?}"))
}

fn save_preprocessed_v5_inner(path: &Path, pre: &Preprocessed, np: usize) -> Result<()> {
    io_probe(FaultSite::StoreIo)?;
    let np = np.max(1);
    let (mut cc, mut cs) = partition_triples(pre, np);
    // Sort rows within each partition: delta compression feeds on runs of
    // nearby ids, and partition contents are order-free for every consumer
    // (the segmented layouts already reorder rows across partitions).
    for p in &mut cc {
        p.sort_unstable_by_key(|t| {
            (t.triple.dst.raw(), t.triple.src.raw(), t.triple.op.0, t.ccid.0)
        });
    }
    for p in &mut cs {
        p.sort_unstable_by_key(|t| {
            (t.dst_csid.0, t.triple.dst.raw(), t.triple.src.raw(), t.src_csid.0)
        });
    }
    // cc_of/cs_of round-trip through hash maps, so their order is free
    // too: sorted pairs delta-compress to almost nothing. set_deps and
    // large_components keep their original order (callers observe it).
    let mut cc_of: Vec<(u64, u64)> = pre.cc_of.iter().map(|(&n, &c)| (n, c)).collect();
    cc_of.sort_unstable();
    let mut cs_of: Vec<(u64, u64)> = pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect();
    cs_of.sort_unstable();
    let large: Vec<(u64, u64, u64)> =
        pre.large_components.iter().map(|&(c, n, e)| (c, n as u64, e as u64)).collect();

    let mut blocks: Vec<(Vec<u8>, u64)> = Vec::with_capacity(2 * np + 4);
    for p in &cc {
        blocks.push((compress_columnar(p), p.len() as u64));
    }
    for p in &cs {
        blocks.push((compress_columnar(p), p.len() as u64));
    }
    blocks.push((compress_columnar(&pre.set_deps), pre.set_deps.len() as u64));
    blocks.push((compress_columnar(&cc_of), cc_of.len() as u64));
    blocks.push((compress_columnar(&cs_of), cs_of.len() as u64));
    blocks.push((compress_columnar(&large), large.len() as u64));

    let entries = 2 * np + 4;
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_PRE_V5)?;
    w_u64(&mut w, pre.theta as u64)?;
    w_u64(&mut w, pre.big_threshold as u64)?;
    w_u64(&mut w, pre.epoch)?;
    w_u64(&mut w, pre.workflow_fingerprint)?;
    w_u64(&mut w, pre.shard_index)?;
    w_u64(&mut w, pre.shard_count)?;
    w_u64(&mut w, pre.component_count as u64)?;
    w_u64(&mut w, pre.set_count as u64)?;
    w_u64(&mut w, np as u64)?;
    let mut at = (V4_HEADER_BYTES + entries * 24) as u64;
    for (block, rows) in &blocks {
        w_u64(&mut w, at)?;
        w_u64(&mut w, *rows)?;
        w_u64(&mut w, block.len() as u64)?;
        at += block.len() as u64;
    }
    for (block, _) in &blocks {
        w.write_all(block)?;
    }
    w.flush()?;
    Ok(())
}

/// Save in the previous **v4** (`PSPKPRE4`) uncompressed segmented
/// layout. Kept callable so format comparisons (the `bench_oocore` size
/// gate) and mixed-version fleets can still produce files every reader
/// since PR 6 accepts.
pub fn save_preprocessed_v4(path: &Path, pre: &Preprocessed, num_partitions: usize) -> Result<()> {
    save_preprocessed_v4_inner(path, pre, num_partitions)
        .with_context(|| format!("writing preprocessed file {path:?}"))
}

fn save_preprocessed_v4_inner(path: &Path, pre: &Preprocessed, np: usize) -> Result<()> {
    io_probe(FaultSite::StoreIo)?;
    let np = np.max(1);
    let (cc, cs) = partition_triples(pre, np);

    // Directory of absolute (offset, rows) pairs: np cc segments, np cs
    // segments, then the four unsegmented sections.
    let entries = 2 * np + 4;
    let mut dir: Vec<(u64, u64)> = Vec::with_capacity(entries);
    let mut at = (V4_HEADER_BYTES + entries * 16) as u64;
    let mut section = |rows: usize, record_bytes: usize| {
        dir.push((at, rows as u64));
        at += (rows * record_bytes) as u64;
    };
    for p in &cc {
        section(p.len(), CcTriple::RECORD_BYTES);
    }
    for p in &cs {
        section(p.len(), CsTriple::RECORD_BYTES);
    }
    section(pre.set_deps.len(), SetDep::RECORD_BYTES);
    section(pre.cc_of.len(), <(u64, u64)>::RECORD_BYTES);
    section(pre.cs_of.len(), <(u64, u64)>::RECORD_BYTES);
    section(pre.large_components.len(), <(u64, u64, u64)>::RECORD_BYTES);
    drop(section);

    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_PRE_V4)?;
    w_u64(&mut w, pre.theta as u64)?;
    w_u64(&mut w, pre.big_threshold as u64)?;
    w_u64(&mut w, pre.epoch)?;
    w_u64(&mut w, pre.workflow_fingerprint)?;
    w_u64(&mut w, pre.shard_index)?;
    w_u64(&mut w, pre.shard_count)?;
    w_u64(&mut w, pre.component_count as u64)?;
    w_u64(&mut w, pre.set_count as u64)?;
    w_u64(&mut w, np as u64)?;
    for &(offset, rows) in &dir {
        w_u64(&mut w, offset)?;
        w_u64(&mut w, rows)?;
    }
    let mut buf = Vec::with_capacity(64 * 1024);
    for p in &cc {
        buf.clear();
        for t in p {
            t.encode(&mut buf);
        }
        w.write_all(&buf)?;
    }
    for p in &cs {
        buf.clear();
        for t in p {
            t.encode(&mut buf);
        }
        w.write_all(&buf)?;
    }
    buf.clear();
    for d in &pre.set_deps {
        d.encode(&mut buf);
    }
    for (&n, &c) in &pre.cc_of {
        (n, c).encode(&mut buf);
    }
    for (&n, &c) in &pre.cs_of {
        (n, c).encode(&mut buf);
    }
    for &(ccid, nodes, edges) in &pre.large_components {
        (ccid, nodes as u64, edges as u64).encode(&mut buf);
    }
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Load preprocessed provenance. Pass-stats and timings are not persisted
/// (they are preprocessing-run artifacts, reported at preprocessing time).
/// Accepts v5 (`PSPKPRE5`, compressed segmented) and v4 (`PSPKPRE4`,
/// segmented — both reassembled in partition order), v3 (`PSPKPRE3`), v2
/// (`PSPKPRE2`, workflow-fingerprint and shard fields zeroed) and legacy
/// v1 (`PSPKPRE1`, epoch fields zeroed too) files; errors name the
/// offending path.
pub fn load_preprocessed(path: &Path) -> Result<Preprocessed> {
    load_preprocessed_inner(path)
        .with_context(|| format!("loading preprocessed file {path:?}"))
}

fn load_preprocessed_inner(path: &Path) -> Result<Preprocessed> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic == MAGIC_PRE_V4 || &magic == MAGIC_PRE_V5 {
        // Segmented layouts: reopen through the directory reader and pull
        // every section (queries that want partitions on demand use
        // `SegmentedPre` directly instead).
        drop(r);
        return SegmentedPre::open(path)?.load_all();
    }
    if &magic != MAGIC_PRE_V3 && &magic != MAGIC_PRE_V2 && &magic != MAGIC_PRE_V1 {
        bail!("not a provspark preprocessed file (bad magic)");
    }
    let mut pre = Preprocessed::default();
    if &magic != MAGIC_PRE_V1 {
        // v2 header fields.
        pre.theta = r_u64(&mut r).context("read theta")? as usize;
        pre.big_threshold = r_u64(&mut r).context("read big_threshold")? as usize;
        pre.epoch = r_u64(&mut r).context("read epoch")?;
    }
    if &magic == MAGIC_PRE_V3 {
        // v3 additions.
        pre.workflow_fingerprint =
            r_u64(&mut r).context("read workflow_fingerprint")?;
        pre.shard_index = r_u64(&mut r).context("read shard_index")?;
        pre.shard_count = r_u64(&mut r).context("read shard_count")?;
    }

    let n = r_u64(&mut r).context("read cc_triples count")?;
    let n = checked_count(n, 28, file_len, "cc_triples")?;
    pre.cc_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cc_triples.push(CcTriple { triple, ccid: ComponentId(r_u64(&mut r)?) });
    }
    let n = r_u64(&mut r).context("read cs_triples count")?;
    let n = checked_count(n, 36, file_len, "cs_triples")?;
    pre.cs_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cs_triples.push(CsTriple {
            triple,
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r).context("read set_deps count")?;
    let n = checked_count(n, 16, file_len, "set_deps")?;
    pre.set_deps.reserve(n);
    for _ in 0..n {
        pre.set_deps.push(SetDep {
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r).context("read cc_of count")?;
    let n = checked_count(n, 16, file_len, "cc_of")?;
    pre.cc_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cc_of.insert(k, v);
    }
    let n = r_u64(&mut r).context("read cs_of count")?;
    let n = checked_count(n, 16, file_len, "cs_of")?;
    pre.cs_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cs_of.insert(k, v);
    }
    let n = r_u64(&mut r).context("read large_components count")?;
    let n = checked_count(n, 24, file_len, "large_components")?;
    pre.large_components.reserve(n);
    for _ in 0..n {
        let cc = r_u64(&mut r)?;
        let nodes = r_u64(&mut r)? as usize;
        let edges = r_u64(&mut r)? as usize;
        pre.large_components.push((cc, nodes, edges));
    }
    pre.component_count = r_u64(&mut r).context("read component_count")? as usize;
    pre.set_count = r_u64(&mut r).context("read set_count")? as usize;
    Ok(pre)
}

/// An open v4 (`PSPKPRE4`) or v5 (`PSPKPRE5`, compressed) preprocessed
/// file: header and directory in memory, payload on disk. Any one section
/// is readable with a single seek + sized read, so the out-of-core tier
/// can open a preprocessed index and page in only the partitions a query
/// touches. Every read opens the file independently (no shared handle),
/// mirroring [`crate::storage::SegmentFile`].
#[derive(Debug)]
pub struct SegmentedPre {
    path: PathBuf,
    /// v5 sections are delta+varint columnar blocks; v4 sections are raw
    /// fixed-width records.
    compressed: bool,
    theta: usize,
    big_threshold: usize,
    epoch: u64,
    workflow_fingerprint: u64,
    shard_index: u64,
    shard_count: u64,
    component_count: usize,
    set_count: usize,
    num_partitions: usize,
    /// Absolute (offset, rows, on-disk bytes) per section: `np` cc
    /// segments, `np` cs segments, then set_deps / cc_of / cs_of /
    /// large_components.
    dir: Vec<(u64, u64, u64)>,
}

/// On-disk record size of directory entry `idx` for an `np`-partition
/// file (cc 28, cs 36, set_deps/cc_of/cs_of 16, large_components 24).
fn section_record_bytes(np: usize, idx: usize) -> usize {
    if idx < np {
        CcTriple::RECORD_BYTES
    } else if idx < 2 * np {
        CsTriple::RECORD_BYTES
    } else if idx == 2 * np + 3 {
        <(u64, u64, u64)>::RECORD_BYTES
    } else {
        <(u64, u64)>::RECORD_BYTES
    }
}

impl SegmentedPre {
    /// Open and validate a v4/v5 file: reads only the header and
    /// directory, checks every section lies inside the file. Errors name
    /// the path.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_inner(path)
            .with_context(|| format!("opening segmented preprocessed file {path:?}"))
    }

    fn open_inner(path: &Path) -> Result<Self> {
        io_probe(FaultSite::StoreIo)?;
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("read magic")?;
        let compressed = if &magic == MAGIC_PRE_V5 {
            true
        } else if &magic == MAGIC_PRE_V4 {
            false
        } else {
            bail!("not a segmented (v4/v5) preprocessed file (bad magic)");
        };
        let entry_bytes: u64 = if compressed { 24 } else { 16 };
        let theta = r_u64(&mut r).context("read theta")? as usize;
        let big_threshold = r_u64(&mut r).context("read big_threshold")? as usize;
        let epoch = r_u64(&mut r).context("read epoch")?;
        let workflow_fingerprint = r_u64(&mut r).context("read workflow_fingerprint")?;
        let shard_index = r_u64(&mut r).context("read shard_index")?;
        let shard_count = r_u64(&mut r).context("read shard_count")?;
        let component_count = r_u64(&mut r).context("read component_count")? as usize;
        let set_count = r_u64(&mut r).context("read set_count")? as usize;
        let np = r_u64(&mut r).context("read partition count")?;
        // The directory itself must fit before its size is trusted.
        np.checked_mul(2)
            .and_then(|e| e.checked_add(4))
            .and_then(|e| e.checked_mul(entry_bytes))
            .filter(|&d| V4_HEADER_BYTES as u64 + d <= file_len)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "partition count {np} is implausible for a {file_len}-byte file: \
                     corrupt or truncated header"
                )
            })?;
        let np = np as usize;
        let entries = 2 * np + 4;
        let mut dir = Vec::with_capacity(entries);
        for i in 0..entries {
            let offset = r_u64(&mut r).with_context(|| format!("read directory entry {i}"))?;
            let rows = r_u64(&mut r).with_context(|| format!("read directory entry {i}"))?;
            let bytes = if compressed {
                r_u64(&mut r).with_context(|| format!("read directory entry {i}"))?
            } else {
                rows.checked_mul(section_record_bytes(np, i) as u64).ok_or_else(|| {
                    anyhow::anyhow!("section {i} row count {rows} overflows: corrupt directory")
                })?
            };
            dir.push((offset, rows, bytes));
        }
        for (i, &(offset, rows, bytes)) in dir.iter().enumerate() {
            let fits = offset.checked_add(bytes).is_some_and(|end| end <= file_len);
            if !fits {
                bail!(
                    "section {i} ({rows} rows, {bytes} bytes at offset {offset}) exceeds \
                     the {file_len}-byte file: corrupt or truncated"
                );
            }
            // Every compressed row is at least one varint byte per column
            // (≥ 2 columns), so a row count beyond the block size can only
            // be corruption — and it must never size an allocation.
            if compressed && rows > bytes {
                bail!(
                    "section {i} claims {rows} rows in a {bytes}-byte compressed block: \
                     corrupt or truncated directory"
                );
            }
        }
        Ok(Self {
            path: path.to_path_buf(),
            compressed,
            theta,
            big_threshold,
            epoch,
            workflow_fingerprint,
            shard_index,
            shard_count,
            component_count,
            set_count,
            num_partitions: np,
            dir,
        })
    }

    fn read_section<T: ColumnarCodec>(&self, idx: usize) -> Result<Vec<T>> {
        io_probe(FaultSite::SegmentIo)?;
        debug_assert_eq!(T::RECORD_BYTES, section_record_bytes(self.num_partitions, idx));
        let (offset, rows, bytes) = self.dir[idx];
        let mut f = std::fs::File::open(&self.path)?;
        f.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; bytes as usize];
        f.read_exact(&mut buf).context("read section payload")?;
        if self.compressed {
            decompress_columnar(&buf, rows as usize).context("decompress section block")
        } else {
            Ok(buf.chunks_exact(T::RECORD_BYTES).map(T::decode).collect())
        }
    }

    /// Everything except the two triple sections: the header-adjacent
    /// maps and summaries a zero-copy session build needs eagerly
    /// (`cc_triples`/`cs_triples` stay empty — they are what demand
    /// paging serves per partition).
    pub fn load_light(&self) -> Result<Preprocessed> {
        let mut pre = Preprocessed {
            theta: self.theta,
            big_threshold: self.big_threshold,
            epoch: self.epoch,
            workflow_fingerprint: self.workflow_fingerprint,
            shard_index: self.shard_index,
            shard_count: self.shard_count,
            component_count: self.component_count,
            set_count: self.set_count,
            ..Default::default()
        };
        pre.set_deps = self.set_deps()?;
        pre.cc_of = self.cc_of()?;
        pre.cs_of = self.cs_of()?;
        pre.large_components = self.large_components()?;
        Ok(pre)
    }

    /// The whole index, reassembled in partition order — what
    /// [`load_preprocessed`] returns for a segmented file.
    pub fn load_all(&self) -> Result<Preprocessed> {
        let mut pre = self.load_light()?;
        for i in 0..self.num_partitions {
            pre.cc_triples.extend(self.cc_partition(i)?);
            pre.cs_triples.extend(self.cs_partition(i)?);
        }
        Ok(pre)
    }

    /// Whether sections are compressed columnar blocks (v5) or raw
    /// fixed-width records (v4).
    pub fn is_compressed(&self) -> bool {
        self.compressed
    }

    pub fn theta(&self) -> usize {
        self.theta
    }

    pub fn big_threshold(&self) -> usize {
        self.big_threshold
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn workflow_fingerprint(&self) -> u64 {
        self.workflow_fingerprint
    }

    pub fn shard_index(&self) -> u64 {
        self.shard_index
    }

    pub fn shard_count(&self) -> u64 {
        self.shard_count
    }

    pub fn component_count(&self) -> usize {
        self.component_count
    }

    pub fn set_count(&self) -> usize {
        self.set_count
    }

    /// Hash partitions per triple section (the writer's `num_partitions`).
    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    /// Row count of cc partition `i` (from the directory — no IO).
    pub fn cc_rows(&self, i: usize) -> usize {
        self.dir[i].1 as usize
    }

    /// Row count of cs partition `i` (from the directory — no IO).
    pub fn cs_rows(&self, i: usize) -> usize {
        self.dir[self.num_partitions + i].1 as usize
    }

    /// On-disk payload bytes of cc partition `i` — the compressed block
    /// size in v5 (from the directory — no IO).
    pub fn cc_bytes(&self, i: usize) -> u64 {
        self.dir[i].2
    }

    /// On-disk payload bytes of cs partition `i` (see [`Self::cc_bytes`]).
    pub fn cs_bytes(&self, i: usize) -> u64 {
        self.dir[self.num_partitions + i].2
    }

    /// Component-tagged triples of partition `i` — the rows whose `dst`
    /// hashes to engine partition `i`. One seek + one sized read; the
    /// `io:segment` fault site is probed.
    pub fn cc_partition(&self, i: usize) -> Result<Vec<CcTriple>> {
        anyhow::ensure!(
            i < self.num_partitions,
            "cc partition {i} out of range ({} partitions)",
            self.num_partitions
        );
        self.read_section(i)
            .with_context(|| format!("reading cc partition {i} of {:?}", self.path))
    }

    /// Set-tagged triples of partition `i` (keyed by `dst_csid`).
    pub fn cs_partition(&self, i: usize) -> Result<Vec<CsTriple>> {
        anyhow::ensure!(
            i < self.num_partitions,
            "cs partition {i} out of range ({} partitions)",
            self.num_partitions
        );
        self.read_section(self.num_partitions + i)
            .with_context(|| format!("reading cs partition {i} of {:?}", self.path))
    }

    /// The set-dependency edges (one unsegmented section).
    pub fn set_deps(&self) -> Result<Vec<SetDep>> {
        self.read_section(2 * self.num_partitions)
            .with_context(|| format!("reading set_deps of {:?}", self.path))
    }

    /// The node → component map.
    pub fn cc_of(&self) -> Result<FxHashMap<u64, u64>> {
        let pairs: Vec<(u64, u64)> = self
            .read_section(2 * self.num_partitions + 1)
            .with_context(|| format!("reading cc_of of {:?}", self.path))?;
        Ok(pairs.into_iter().collect())
    }

    /// The node → set map.
    pub fn cs_of(&self) -> Result<FxHashMap<u64, u64>> {
        let pairs: Vec<(u64, u64)> = self
            .read_section(2 * self.num_partitions + 2)
            .with_context(|| format!("reading cs_of of {:?}", self.path))?;
        Ok(pairs.into_iter().collect())
    }

    /// The large-component summaries `(ccid, nodes, edges)`.
    pub fn large_components(&self) -> Result<Vec<(u64, usize, usize)>> {
        let rows: Vec<(u64, u64, u64)> = self
            .read_section(2 * self.num_partitions + 3)
            .with_context(|| format!("reading large_components of {:?}", self.path))?;
        Ok(rows.into_iter().map(|(c, n, e)| (c, n as usize, e as usize)).collect())
    }
}

/// [`save_trace`] through a temp file + atomic rename: an interrupted
/// write never destroys an existing file at `path`. This is what the CLI
/// `ingest` subcommand persists with — it updates its own inputs in place,
/// so a mid-write crash must not lose the only copy of the index.
pub fn save_trace_atomic(path: &Path, trace: &Trace) -> Result<()> {
    save_atomic(path, |tmp| save_trace(tmp, trace))
}

/// [`save_preprocessed`] through a temp file + atomic rename (see
/// [`save_trace_atomic`]).
pub fn save_preprocessed_atomic(path: &Path, pre: &Preprocessed) -> Result<()> {
    save_atomic(path, |tmp| save_preprocessed(tmp, pre))
}

/// `EXDEV` — "invalid cross-device link" — on Linux, macOS and the BSDs.
/// `rename(2)` returns it when source and destination are on different
/// filesystems, where an atomic move is impossible.
const EXDEV: i32 = 18;

fn save_atomic(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    write(&tmp)?;
    // fsync the temp file before the rename: without it a crash shortly
    // after the rename can leave the *new* name pointing at unflushed (and
    // therefore possibly empty/truncated) data — losing the only copy the
    // rename was supposed to protect.
    std::fs::File::open(&tmp)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsyncing {tmp:?} before the atomic rename"))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let crosses_fs = e.raw_os_error() == Some(EXDEV);
        let _ = std::fs::remove_file(&tmp);
        if crosses_fs {
            // The temp file sits next to the destination, so this needs an
            // exotic layout (e.g. a mount point or cross-device symlink at
            // the destination path) — but when it happens, the failure mode
            // deserves a precise name rather than a generic rename error.
            bail!(
                "cannot atomically move {tmp:?} into place at {path:?}: rename(2) \
                 reported EXDEV (the two paths resolve to different filesystems, so \
                 an atomic replace is impossible there)"
            );
        }
        return Err(anyhow::Error::new(e)
            .context(format!("moving {tmp:?} into place at {path:?}")));
    }
    // Durability of the *rename* needs a directory fsync; best-effort (not
    // every filesystem supports fsync on a directory handle) — the data
    // itself was already synced above.
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// CSV export of a trace (`src,dst,op`) for external inspection.
pub fn export_csv(path: &Path, trace: &Trace) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "src,dst,op")?;
    for t in &trace.triples {
        writeln!(w, "{},{},{}", t.src.raw(), t.dst.raw(), t.op.0)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("provspark_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// The version-independent monolithic body the v1–v3 layouts shared
    /// (they differ only in the header fields after the magic) — a frozen
    /// fixture writer, kept in sync with nothing: old files must keep
    /// loading verbatim.
    fn write_sections(w: &mut impl Write, pre: &Preprocessed) {
        w_u64(w, pre.cc_triples.len() as u64).unwrap();
        for t in &pre.cc_triples {
            w_triple(w, &t.triple).unwrap();
            w_u64(w, t.ccid.0).unwrap();
        }
        w_u64(w, pre.cs_triples.len() as u64).unwrap();
        for t in &pre.cs_triples {
            w_triple(w, &t.triple).unwrap();
            w_u64(w, t.src_csid.0).unwrap();
            w_u64(w, t.dst_csid.0).unwrap();
        }
        w_u64(w, pre.set_deps.len() as u64).unwrap();
        for d in &pre.set_deps {
            w_u64(w, d.src_csid.0).unwrap();
            w_u64(w, d.dst_csid.0).unwrap();
        }
        w_u64(w, pre.cc_of.len() as u64).unwrap();
        for (&n, &c) in &pre.cc_of {
            w_u64(w, n).unwrap();
            w_u64(w, c).unwrap();
        }
        w_u64(w, pre.cs_of.len() as u64).unwrap();
        for (&n, &c) in &pre.cs_of {
            w_u64(w, n).unwrap();
            w_u64(w, c).unwrap();
        }
        w_u64(w, pre.large_components.len() as u64).unwrap();
        for &(cc, nodes, edges) in &pre.large_components {
            w_u64(w, cc).unwrap();
            w_u64(w, nodes as u64).unwrap();
            w_u64(w, edges as u64).unwrap();
        }
        w_u64(w, pre.component_count as u64).unwrap();
        w_u64(w, pre.set_count as u64).unwrap();
    }

    /// The exact v3 (`PSPKPRE3`) layout as PRs 3–6 wrote it — a
    /// regression fixture for backwards compatibility.
    fn save_preprocessed_v3(path: &std::path::Path, pre: &Preprocessed) {
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(b"PSPKPRE3").unwrap();
        w_u64(&mut w, pre.theta as u64).unwrap();
        w_u64(&mut w, pre.big_threshold as u64).unwrap();
        w_u64(&mut w, pre.epoch).unwrap();
        w_u64(&mut w, pre.workflow_fingerprint).unwrap();
        w_u64(&mut w, pre.shard_index).unwrap();
        w_u64(&mut w, pre.shard_count).unwrap();
        write_sections(&mut w, pre);
        w.flush().unwrap();
    }

    /// v4 reassembles triples in partition order; compare as multisets.
    fn sorted_cc(mut v: Vec<CcTriple>) -> Vec<CcTriple> {
        v.sort_by_key(|t| (t.triple.src.raw(), t.triple.dst.raw(), t.triple.op.0, t.ccid.0));
        v
    }

    fn sorted_cs(mut v: Vec<CsTriple>) -> Vec<CsTriple> {
        v.sort_by_key(|t| (t.triple.src.raw(), t.triple.dst.raw(), t.src_csid.0, t.dst_csid.0));
        v
    }

    #[test]
    fn trace_roundtrip() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let p = tmp("trace.bin");
        save_trace(&p, &trace).unwrap();
        let loaded = load_trace(&p).unwrap();
        assert_eq!(trace.triples, loaded.triples);
    }

    #[test]
    fn preprocessed_roundtrip() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let p = tmp("pre.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(sorted_cc(pre.cc_triples.clone()), sorted_cc(loaded.cc_triples));
        assert_eq!(sorted_cs(pre.cs_triples.clone()), sorted_cs(loaded.cs_triples));
        assert_eq!(pre.set_deps, loaded.set_deps);
        assert_eq!(pre.cc_of, loaded.cc_of);
        assert_eq!(pre.cs_of, loaded.cs_of);
        assert_eq!(pre.large_components, loaded.large_components);
        assert_eq!(pre.component_count, loaded.component_count);
        assert_eq!(pre.set_count, loaded.set_count);
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("bogus.bin");
        std::fs::write(&p, b"NOTMAGIC123").unwrap();
        assert!(load_trace(&p).is_err());
        assert!(load_preprocessed(&p).is_err());
    }

    #[test]
    fn atomic_save_replaces_and_leaves_no_temp() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let tp = tmp("atomic_trace.bin");
        let pp = tmp("atomic_pre.bin");
        // Seed the destination with garbage an interrupted write must not
        // be able to leave behind.
        std::fs::write(&tp, b"GARBAGE").unwrap();
        save_trace_atomic(&tp, &trace).unwrap();
        save_preprocessed_atomic(&pp, &pre).unwrap();
        assert_eq!(load_trace(&tp).unwrap().triples, trace.triples);
        assert_eq!(load_preprocessed(&pp).unwrap().epoch, pre.epoch);
        for p in [&tp, &pp] {
            let mut t = p.as_os_str().to_owned();
            t.push(".tmp");
            assert!(!std::path::PathBuf::from(t).exists(), "temp file left behind");
        }
    }

    #[test]
    fn roundtrip_preserves_incremental_epoch_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 7; // as if 7 batches were ingested
        assert_eq!(pre.theta, 200);
        let p = tmp("pre_epoch.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 200);
        assert_eq!(loaded.big_threshold, 100);
        assert_eq!(loaded.epoch, 7);
        // …alongside everything the query engines need.
        assert_eq!(sorted_cc(pre.cc_triples.clone()), sorted_cc(loaded.cc_triples));
        assert_eq!(pre.cs_of, loaded.cs_of);
    }

    #[test]
    fn segmented_roundtrip_preserves_fingerprint_and_shard_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        assert_ne!(pre.workflow_fingerprint, 0, "preprocess records the workflow");
        pre.shard_index = 2;
        pre.shard_count = 4;
        let p = tmp("pre_v5.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.workflow_fingerprint, pre.workflow_fingerprint);
        assert_eq!(loaded.shard_index, 2);
        assert_eq!(loaded.shard_count, 4);
        assert_eq!(sorted_cc(loaded.cc_triples), sorted_cc(pre.cc_triples.clone()));
        assert_eq!(sorted_cs(loaded.cs_triples), sorted_cs(pre.cs_triples.clone()));
    }

    #[test]
    fn segmented_partitions_match_engine_partitioning() {
        use crate::minispark::HashPartitioner;
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let p = tmp("pre_v5_parts.bin");
        save_preprocessed_with_partitions(&p, &pre, 8).unwrap();
        let seg = SegmentedPre::open(&p).unwrap();
        assert!(seg.is_compressed(), "the default writer produces v5 blocks");
        assert_eq!(seg.num_partitions(), 8);
        assert_eq!(seg.theta(), pre.theta);
        assert_eq!(seg.epoch(), pre.epoch);
        assert_eq!(seg.workflow_fingerprint(), pre.workflow_fingerprint);
        assert_eq!(seg.component_count(), pre.component_count);
        assert_eq!(seg.set_count(), pre.set_count);
        let parter = HashPartitioner::new(8);
        let mut cc_all = Vec::new();
        let mut cs_all = Vec::new();
        for i in 0..8 {
            let cc = seg.cc_partition(i).unwrap();
            assert_eq!(cc.len(), seg.cc_rows(i), "directory row count");
            for t in &cc {
                assert_eq!(
                    parter.partition_of(t.triple.dst.raw()),
                    i,
                    "cc segment {i} must hold exactly engine partition {i}'s rows"
                );
            }
            cc_all.extend(cc);
            let cs = seg.cs_partition(i).unwrap();
            assert_eq!(cs.len(), seg.cs_rows(i));
            for t in &cs {
                assert_eq!(parter.partition_of(t.dst_csid.0), i);
            }
            cs_all.extend(cs);
        }
        assert_eq!(sorted_cc(cc_all), sorted_cc(pre.cc_triples.clone()));
        assert_eq!(sorted_cs(cs_all), sorted_cs(pre.cs_triples.clone()));
        assert_eq!(seg.set_deps().unwrap(), pre.set_deps);
        assert_eq!(seg.cc_of().unwrap(), pre.cc_of);
        assert_eq!(seg.cs_of().unwrap(), pre.cs_of);
        assert_eq!(seg.large_components().unwrap(), pre.large_components);
    }

    #[test]
    fn v3_file_still_loads_identically() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 5;
        pre.shard_index = 1;
        pre.shard_count = 2;
        let p = tmp("pre_v3_frozen.bin");
        save_preprocessed_v3(&p, &pre);
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, pre.theta);
        assert_eq!(loaded.epoch, 5);
        assert_eq!(loaded.workflow_fingerprint, pre.workflow_fingerprint);
        assert_eq!(loaded.shard_index, 1);
        assert_eq!(loaded.shard_count, 2);
        // Monolithic sections load verbatim — original order preserved.
        assert_eq!(loaded.cc_triples, pre.cc_triples);
        assert_eq!(loaded.cs_triples, pre.cs_triples);
        assert_eq!(loaded.set_deps, pre.set_deps);
        assert_eq!(loaded.cc_of, pre.cc_of);
        assert_eq!(loaded.cs_of, pre.cs_of);
        assert_eq!(loaded.large_components, pre.large_components);
        assert_eq!(loaded.component_count, pre.component_count);
        assert_eq!(loaded.set_count, pre.set_count);
    }

    /// The exact v4 (`PSPKPRE4`) layout as PRs 6–8 wrote it — a frozen
    /// regression fixture for backwards compatibility, kept in sync with
    /// nothing (that is the point: old files must keep loading verbatim).
    fn save_preprocessed_v4_frozen(path: &std::path::Path, pre: &Preprocessed, np: usize) {
        use crate::minispark::HashPartitioner;
        let parter = HashPartitioner::new(np);
        let mut cc: Vec<Vec<CcTriple>> = vec![Vec::new(); np];
        for t in &pre.cc_triples {
            cc[parter.partition_of(t.triple.dst.raw())].push(*t);
        }
        let mut cs: Vec<Vec<CsTriple>> = vec![Vec::new(); np];
        for t in &pre.cs_triples {
            cs[parter.partition_of(t.dst_csid.0)].push(*t);
        }
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(b"PSPKPRE4").unwrap();
        for v in [
            pre.theta as u64,
            pre.big_threshold as u64,
            pre.epoch,
            pre.workflow_fingerprint,
            pre.shard_index,
            pre.shard_count,
            pre.component_count as u64,
            pre.set_count as u64,
            np as u64,
        ] {
            w_u64(&mut w, v).unwrap();
        }
        let entries = 2 * np + 4;
        let mut at = (80 + entries * 16) as u64;
        let mut dir: Vec<(u64, u64)> = Vec::new();
        for p in &cc {
            dir.push((at, p.len() as u64));
            at += (p.len() * 28) as u64;
        }
        for p in &cs {
            dir.push((at, p.len() as u64));
            at += (p.len() * 36) as u64;
        }
        for rows in [pre.set_deps.len(), pre.cc_of.len(), pre.cs_of.len()] {
            dir.push((at, rows as u64));
            at += (rows * 16) as u64;
        }
        dir.push((at, pre.large_components.len() as u64));
        for (offset, rows) in dir {
            w_u64(&mut w, offset).unwrap();
            w_u64(&mut w, rows).unwrap();
        }
        for p in &cc {
            for t in p {
                w_triple(&mut w, &t.triple).unwrap();
                w_u64(&mut w, t.ccid.0).unwrap();
            }
        }
        for p in &cs {
            for t in p {
                w_triple(&mut w, &t.triple).unwrap();
                w_u64(&mut w, t.src_csid.0).unwrap();
                w_u64(&mut w, t.dst_csid.0).unwrap();
            }
        }
        for d in &pre.set_deps {
            w_u64(&mut w, d.src_csid.0).unwrap();
            w_u64(&mut w, d.dst_csid.0).unwrap();
        }
        for (&n, &c) in &pre.cc_of {
            w_u64(&mut w, n).unwrap();
            w_u64(&mut w, c).unwrap();
        }
        for (&n, &c) in &pre.cs_of {
            w_u64(&mut w, n).unwrap();
            w_u64(&mut w, c).unwrap();
        }
        for &(ccid, nodes, edges) in &pre.large_components {
            w_u64(&mut w, ccid).unwrap();
            w_u64(&mut w, nodes as u64).unwrap();
            w_u64(&mut w, edges as u64).unwrap();
        }
        w.flush().unwrap();
    }

    #[test]
    fn v4_file_still_loads_identically() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 9;
        pre.shard_index = 1;
        pre.shard_count = 2;
        let p = tmp("pre_v4_frozen.bin");
        save_preprocessed_v4_frozen(&p, &pre, 8);
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, pre.theta);
        assert_eq!(loaded.epoch, 9);
        assert_eq!(loaded.workflow_fingerprint, pre.workflow_fingerprint);
        assert_eq!(loaded.shard_index, 1);
        assert_eq!(loaded.shard_count, 2);
        assert_eq!(sorted_cc(loaded.cc_triples), sorted_cc(pre.cc_triples.clone()));
        assert_eq!(sorted_cs(loaded.cs_triples), sorted_cs(pre.cs_triples.clone()));
        assert_eq!(loaded.set_deps, pre.set_deps);
        assert_eq!(loaded.cc_of, pre.cc_of);
        assert_eq!(loaded.cs_of, pre.cs_of);
        assert_eq!(loaded.large_components, pre.large_components);
        assert_eq!(loaded.component_count, pre.component_count);
        assert_eq!(loaded.set_count, pre.set_count);
        // The production v4 writer still emits the frozen layout, byte for
        // byte, and readers classify it as uncompressed.
        let p2 = tmp("pre_v4_prod.bin");
        save_preprocessed_v4(&p2, &pre, 8).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), std::fs::read(&p2).unwrap());
        assert!(!SegmentedPre::open(&p).unwrap().is_compressed());
    }

    #[test]
    fn v4_truncated_and_corrupt_files_name_the_path() {
        // Implausible partition count: the directory could never fit.
        let p = tmp("v4_huge_np.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE4");
        bytes.extend_from_slice(&[0u8; 8 * 8]); // 8 zero header fields
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // partition count
        std::fs::write(&p, bytes).unwrap();
        for err in [
            format!("{:#}", SegmentedPre::open(&p).unwrap_err()),
            format!("{:#}", load_preprocessed(&p).unwrap_err()),
        ] {
            assert!(
                err.contains("v4_huge_np.bin") && err.contains("implausible"),
                "expected a named implausible-count error: {err}"
            );
        }

        // A directory whose one section overruns the file.
        let p = tmp("v4_overrun.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE4");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        bytes.extend_from_slice(&1u64.to_le_bytes()); // np = 1
        // 6 directory entries: cc0 claims 1000 rows with no payload.
        bytes.extend_from_slice(&176u64.to_le_bytes()); // offset past directory
        bytes.extend_from_slice(&1000u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 5 * 16]);
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", SegmentedPre::open(&p).unwrap_err());
        assert!(
            err.contains("v4_overrun.bin") && err.contains("exceeds"),
            "error must name the path and the overrun: {err}"
        );
    }

    #[test]
    fn v5_truncated_and_corrupt_files_name_the_path() {
        // Implausible partition count: the directory could never fit.
        let p = tmp("v5_huge_np.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE5");
        bytes.extend_from_slice(&[0u8; 8 * 8]); // 8 zero header fields
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // partition count
        std::fs::write(&p, bytes).unwrap();
        for err in [
            format!("{:#}", SegmentedPre::open(&p).unwrap_err()),
            format!("{:#}", load_preprocessed(&p).unwrap_err()),
        ] {
            assert!(
                err.contains("v5_huge_np.bin") && err.contains("implausible"),
                "expected a named implausible-count error: {err}"
            );
        }

        // A compressed block whose bytes overrun the file.
        let p = tmp("v5_overrun.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE5");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        bytes.extend_from_slice(&1u64.to_le_bytes()); // np = 1
        // 6 directory entries of 24 bytes: cc0 claims a 1000-byte block
        // with no payload behind it.
        bytes.extend_from_slice(&224u64.to_le_bytes()); // offset past directory
        bytes.extend_from_slice(&10u64.to_le_bytes()); // rows
        bytes.extend_from_slice(&1000u64.to_le_bytes()); // block bytes
        bytes.extend_from_slice(&[0u8; 5 * 24]);
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", SegmentedPre::open(&p).unwrap_err());
        assert!(
            err.contains("v5_overrun.bin") && err.contains("exceeds"),
            "error must name the path and the overrun: {err}"
        );

        // A directory claiming more rows than the block has bytes: caught
        // at open, before any row-count-sized allocation.
        let p = tmp("v5_rows_gt_bytes.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE5");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&224u64.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // rows
        bytes.extend_from_slice(&2u64.to_le_bytes()); // block bytes
        bytes.extend_from_slice(&[0u8; 5 * 24]);
        bytes.extend_from_slice(&[0u8; 2]); // the 2-byte "block"
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", SegmentedPre::open(&p).unwrap_err());
        assert!(
            err.contains("v5_rows_gt_bytes.bin") && err.contains("claims"),
            "expected a named rows-vs-bytes error: {err}"
        );

        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);

        // Payload truncated after a successful open: the section read
        // fails with the path and the section named.
        let p = tmp("v5_trunc_payload.bin");
        save_preprocessed_with_partitions(&p, &pre, 4).unwrap();
        let seg = SegmentedPre::open(&p).unwrap();
        let full = std::fs::read(&p).unwrap();
        // Keep only the header + directory (np = 4 → 80 + 12×24 bytes):
        // every payload read must now come up short.
        std::fs::write(&p, &full[..80 + 12 * 24]).unwrap();
        let err = format!("{:#}", seg.cs_of().unwrap_err());
        assert!(
            err.contains("v5_trunc_payload.bin") && err.contains("cs_of"),
            "error must name the path and the section: {err}"
        );

        // Garbage inside a block body: the varint decoder must error (never
        // panic), naming the path and the partition.
        let p = tmp("v5_garbage_block.bin");
        save_preprocessed_with_partitions(&p, &pre, 4).unwrap();
        let seg = SegmentedPre::open(&p).unwrap();
        let mut full = std::fs::read(&p).unwrap();
        let payload_at = 80 + 12 * 24;
        for b in &mut full[payload_at..] {
            *b = 0xff;
        }
        std::fs::write(&p, full).unwrap();
        let mut failures = 0;
        for i in 0..4 {
            if seg.cc_rows(i) == 0 {
                continue;
            }
            let err = format!("{:#}", seg.cc_partition(i).unwrap_err());
            assert!(
                err.contains("v5_garbage_block.bin") && err.contains(&format!("partition {i}")),
                "expected a named decode error: {err}"
            );
            failures += 1;
        }
        assert!(failures > 0, "the generated trace must fill at least one cc partition");
    }

    #[test]
    fn v5_is_measurably_smaller_than_v4() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let p5 = tmp("size_v5.bin");
        let p4 = tmp("size_v4.bin");
        save_preprocessed_with_partitions(&p5, &pre, 16).unwrap();
        save_preprocessed_v4(&p4, &pre, 16).unwrap();
        let (s5, s4) =
            (std::fs::metadata(&p5).unwrap().len(), std::fs::metadata(&p4).unwrap().len());
        assert!(
            s5 * 10 < s4 * 9,
            "v5 must be ≥10% smaller than v4 on a generated trace: {s5} vs {s4}"
        );
        // And both load to the same index.
        let (l5, l4) = (load_preprocessed(&p5).unwrap(), load_preprocessed(&p4).unwrap());
        assert_eq!(sorted_cc(l5.cc_triples), sorted_cc(l4.cc_triples));
        assert_eq!(sorted_cs(l5.cs_triples), sorted_cs(l4.cs_triples));
        assert_eq!(l5.set_deps, l4.set_deps);
        assert_eq!(l5.cc_of, l4.cc_of);
    }

    /// The exact v2 (`PSPKPRE2`) layout as PR 3 wrote it — a regression
    /// fixture for backwards compatibility, kept in sync with nothing (that
    /// is the point: old files must keep loading verbatim).
    fn save_preprocessed_v2(path: &std::path::Path, pre: &Preprocessed) {
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(b"PSPKPRE2").unwrap();
        w_u64(&mut w, pre.theta as u64).unwrap();
        w_u64(&mut w, pre.big_threshold as u64).unwrap();
        w_u64(&mut w, pre.epoch).unwrap();
        write_sections(&mut w, pre);
        w.flush().unwrap();
    }

    #[test]
    fn v2_file_loads_with_zeroed_fingerprint_and_shard_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 3;
        let p = tmp("pre_v2.bin");
        save_preprocessed_v2(&p, &pre);
        let loaded = load_preprocessed(&p).unwrap();
        // v2 header fields survive…
        assert_eq!(loaded.theta, 200);
        assert_eq!(loaded.big_threshold, 100);
        assert_eq!(loaded.epoch, 3);
        // …the v3 additions load as "unrecorded"…
        assert_eq!(loaded.workflow_fingerprint, 0, "v2 has no recorded workflow");
        assert_eq!(loaded.shard_index, 0);
        assert_eq!(loaded.shard_count, 0);
        // …and the body is intact.
        assert_eq!(loaded.cc_triples, pre.cc_triples);
        assert_eq!(loaded.cs_triples, pre.cs_triples);
        assert_eq!(loaded.cc_of, pre.cc_of);
        assert_eq!(loaded.cs_of, pre.cs_of);
        assert_eq!(loaded.set_deps, pre.set_deps);
        assert_eq!(loaded.large_components, pre.large_components);
        assert_eq!(loaded.component_count, pre.component_count);
        assert_eq!(loaded.set_count, pre.set_count);
    }

    #[test]
    fn legacy_v1_file_loads_with_zeroed_epoch_fields() {
        // A minimal empty v1 file: old magic + the 8 zero section counts
        // (cc, cs, deps, cc_of, cs_of, large, component_count, set_count).
        let p = tmp("pre_v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE1");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        std::fs::write(&p, bytes).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 0, "v1 has no recorded θ");
        assert_eq!(loaded.epoch, 0);
        assert_eq!(loaded.workflow_fingerprint, 0);
        assert_eq!(loaded.shard_count, 0);
        assert!(loaded.cc_triples.is_empty());
    }

    #[test]
    fn load_errors_name_the_offending_path() {
        let missing = tmp("definitely_missing.bin");
        let _ = std::fs::remove_file(&missing);
        for err in [
            format!("{:#}", load_trace(&missing).unwrap_err()),
            format!("{:#}", load_preprocessed(&missing).unwrap_err()),
        ] {
            assert!(
                err.contains("definitely_missing.bin"),
                "error must name the path: {err}"
            );
        }
        // Truncated file: magic only, sections missing.
        let p = tmp("truncated.bin");
        std::fs::write(&p, b"PSPKPRE2").unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(err.contains("truncated.bin"), "error must name the path: {err}");
    }

    /// A flipped bit in a count field must come back as a named error, not
    /// an allocation-failure abort: every count is validated against the
    /// file's actual size before it sizes a `Vec`/map.
    #[test]
    fn implausible_counts_are_errors_not_aborts() {
        // Trace whose header claims u64::MAX triples in a 16-byte body.
        let p = tmp("huge_trace_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKTRC1");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        assert!(
            err.contains("huge_trace_count.bin") && err.contains("implausible"),
            "expected a named implausible-count error: {err}"
        );

        // Preprocessed v3 whose first section count is u64::MAX.
        let p = tmp("huge_pre_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 6 * 8]); // zeroed v3 header
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(
            err.contains("huge_pre_count.bin")
                && err.contains("cc_triples")
                && err.contains("implausible"),
            "expected a named implausible-count error: {err}"
        );
    }

    #[test]
    fn short_header_and_truncated_body_name_the_path() {
        // v3 header cut off after two of the six fields.
        let p = tmp("short_header.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 2 * 8]);
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(
            err.contains("short_header.bin") && err.contains("epoch"),
            "expected the missing header field to be named: {err}"
        );

        // Plausible count (2 cc_triples would fit in the file if the header
        // were honest about the rest) but the records themselves are absent.
        let p = tmp("truncated_body.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 6 * 8]);
        bytes.extend_from_slice(&2u64.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(err.contains("truncated_body.bin"), "error must name the path: {err}");

        // Trace truncated mid-record.
        let p = tmp("truncated_trace.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKTRC1");
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 10]); // half a 20-byte triple record
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        assert!(err.contains("truncated_trace.bin"), "error must name the path: {err}");
    }

    #[test]
    fn injected_store_io_faults_surface_as_errors() {
        use crate::fault::{install_io_faults, FaultInjector, FaultPlan};
        use std::sync::Arc;
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let p = tmp("faulted_store.bin");
        save_trace(&p, &trace).unwrap();
        let plan: FaultPlan = "io:store:1.0,seed=4".parse().unwrap();
        install_io_faults(Some(Arc::new(FaultInjector::new(plan))));
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        install_io_faults(None);
        assert!(err.contains("injected"), "expected the injected fault: {err}");
        assert_eq!(load_trace(&p).unwrap().triples, trace.triples);
    }

    #[test]
    fn csv_export_has_header_and_rows() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let p = tmp("trace.csv");
        export_csv(&p, &trace).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("src,dst,op\n"));
        assert_eq!(text.lines().count(), trace.len() + 1);
    }
}
