//! On-disk persistence for traces and preprocessed provenance.
//!
//! The paper stores provenance on HDFS and pre-computes components/sets
//! once; we persist the same artifacts locally in a simple length-prefixed
//! little-endian binary format (with a CSV export for inspection).
//!
//! Preprocessed files are written in the **v3** layout (`PSPKPRE3`), whose
//! header records the incremental-epoch fields — θ, the big-set bound, and
//! the epoch counter — plus the workflow fingerprint
//! ([`crate::workflow::workflow_fingerprint`], so a reloaded index can
//! refuse ingestion under a mismatched workflow) and the component-space
//! shard assignment (`shard_index`/`shard_count`, 0/0 = unsharded — see
//! [`crate::provenance::shard`]). v2 files (`PSPKPRE2`, pre-fingerprint)
//! and v1 files (`PSPKPRE1`, pre-epoch) still load, with the missing
//! header fields zeroed — a v1 index answers queries but refuses ingestion
//! until re-preprocessed, and a v2 index ingests without workflow
//! validation (fingerprint unrecorded).

use crate::fault::{io_probe, FaultSite};
use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::util::ids::{AttrValueId, ComponentId, OpId, SetId};
use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC_TRACE: &[u8; 8] = b"PSPKTRC1";
const MAGIC_PRE_V1: &[u8; 8] = b"PSPKPRE1";
const MAGIC_PRE_V2: &[u8; 8] = b"PSPKPRE2";
const MAGIC_PRE: &[u8; 8] = b"PSPKPRE3";

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Validate an on-disk record count against the file's actual size before
/// any allocation sized by it. A flipped bit (or a file truncated mid-
/// header) can make a count field claim, say, `u64::MAX` records; feeding
/// that into `Vec::with_capacity` aborts the process on allocation failure
/// instead of returning an error. `record_bytes` is the fixed on-disk size
/// of one record, so `n` records can never be genuine unless
/// `n * record_bytes` fits in the file.
fn checked_count(n: u64, record_bytes: u64, file_len: u64, what: &str) -> Result<usize> {
    match n.checked_mul(record_bytes) {
        Some(bytes) if bytes <= file_len => Ok(n as usize),
        _ => bail!(
            "{what} count {n} is implausible for a {file_len}-byte file \
             ({record_bytes} bytes per record): corrupt or truncated header"
        ),
    }
}

fn w_triple(w: &mut impl Write, t: &ProvTriple) -> Result<()> {
    w_u64(w, t.src.raw())?;
    w_u64(w, t.dst.raw())?;
    w_u32(w, t.op.0)
}

fn r_triple(r: &mut impl Read) -> Result<ProvTriple> {
    Ok(ProvTriple::new(
        AttrValueId(r_u64(r)?),
        AttrValueId(r_u64(r)?),
        OpId(r_u32(r)?),
    ))
}

/// Save a raw trace.
pub fn save_trace(path: &Path, trace: &Trace) -> Result<()> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_TRACE)?;
    w_u64(&mut w, trace.triples.len() as u64)?;
    for t in &trace.triples {
        w_triple(&mut w, t)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a raw trace. Errors name the offending path.
pub fn load_trace(path: &Path) -> Result<Trace> {
    load_trace_inner(path).with_context(|| format!("loading trace file {path:?}"))
}

fn load_trace_inner(path: &Path) -> Result<Trace> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC_TRACE {
        bail!("not a provspark trace file (bad magic)");
    }
    let n = r_u64(&mut r).context("read triple count")?;
    let n = checked_count(n, 20, file_len, "triple")?;
    let mut triples = Vec::with_capacity(n);
    for _ in 0..n {
        triples.push(r_triple(&mut r)?);
    }
    Ok(Trace::new(triples))
}

/// Save preprocessed provenance (everything the query engines need),
/// including the incremental-epoch header (θ / big-set bound / epoch), the
/// workflow fingerprint and the shard assignment.
pub fn save_preprocessed(path: &Path, pre: &Preprocessed) -> Result<()> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_PRE)?;
    // v3 header: the fields incremental ingestion and sharding need to
    // keep going after a reload.
    w_u64(&mut w, pre.theta as u64)?;
    w_u64(&mut w, pre.big_threshold as u64)?;
    w_u64(&mut w, pre.epoch)?;
    w_u64(&mut w, pre.workflow_fingerprint)?;
    w_u64(&mut w, pre.shard_index)?;
    w_u64(&mut w, pre.shard_count)?;
    write_sections(&mut w, pre)?;
    w.flush()?;
    Ok(())
}

/// The version-independent body shared by every preprocessed layout (the
/// v1/v2/v3 formats differ only in the header fields after the magic).
fn write_sections(w: &mut impl Write, pre: &Preprocessed) -> Result<()> {
    w_u64(w, pre.cc_triples.len() as u64)?;
    for t in &pre.cc_triples {
        w_triple(w, &t.triple)?;
        w_u64(w, t.ccid.0)?;
    }
    w_u64(w, pre.cs_triples.len() as u64)?;
    for t in &pre.cs_triples {
        w_triple(w, &t.triple)?;
        w_u64(w, t.src_csid.0)?;
        w_u64(w, t.dst_csid.0)?;
    }
    w_u64(w, pre.set_deps.len() as u64)?;
    for d in &pre.set_deps {
        w_u64(w, d.src_csid.0)?;
        w_u64(w, d.dst_csid.0)?;
    }
    w_u64(w, pre.cc_of.len() as u64)?;
    for (&n, &c) in &pre.cc_of {
        w_u64(w, n)?;
        w_u64(w, c)?;
    }
    w_u64(w, pre.cs_of.len() as u64)?;
    for (&n, &c) in &pre.cs_of {
        w_u64(w, n)?;
        w_u64(w, c)?;
    }
    w_u64(w, pre.large_components.len() as u64)?;
    for &(cc, nodes, edges) in &pre.large_components {
        w_u64(w, cc)?;
        w_u64(w, nodes as u64)?;
        w_u64(w, edges as u64)?;
    }
    w_u64(w, pre.component_count as u64)?;
    w_u64(w, pre.set_count as u64)?;
    Ok(())
}

/// Load preprocessed provenance. Pass-stats and timings are not persisted
/// (they are preprocessing-run artifacts, reported at preprocessing time).
/// Accepts v3 (`PSPKPRE3`), v2 (`PSPKPRE2`, workflow-fingerprint and shard
/// fields zeroed) and legacy v1 (`PSPKPRE1`, epoch fields zeroed too)
/// files; errors name the offending path.
pub fn load_preprocessed(path: &Path) -> Result<Preprocessed> {
    load_preprocessed_inner(path)
        .with_context(|| format!("loading preprocessed file {path:?}"))
}

fn load_preprocessed_inner(path: &Path) -> Result<Preprocessed> {
    io_probe(FaultSite::StoreIo)?;
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC_PRE && &magic != MAGIC_PRE_V2 && &magic != MAGIC_PRE_V1 {
        bail!("not a provspark preprocessed file (bad magic)");
    }
    let mut pre = Preprocessed::default();
    if &magic != MAGIC_PRE_V1 {
        // v2 header fields.
        pre.theta = r_u64(&mut r).context("read theta")? as usize;
        pre.big_threshold = r_u64(&mut r).context("read big_threshold")? as usize;
        pre.epoch = r_u64(&mut r).context("read epoch")?;
    }
    if &magic == MAGIC_PRE {
        // v3 additions.
        pre.workflow_fingerprint =
            r_u64(&mut r).context("read workflow_fingerprint")?;
        pre.shard_index = r_u64(&mut r).context("read shard_index")?;
        pre.shard_count = r_u64(&mut r).context("read shard_count")?;
    }

    let n = r_u64(&mut r).context("read cc_triples count")?;
    let n = checked_count(n, 28, file_len, "cc_triples")?;
    pre.cc_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cc_triples.push(CcTriple { triple, ccid: ComponentId(r_u64(&mut r)?) });
    }
    let n = r_u64(&mut r).context("read cs_triples count")?;
    let n = checked_count(n, 36, file_len, "cs_triples")?;
    pre.cs_triples.reserve(n);
    for _ in 0..n {
        let triple = r_triple(&mut r)?;
        pre.cs_triples.push(CsTriple {
            triple,
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r).context("read set_deps count")?;
    let n = checked_count(n, 16, file_len, "set_deps")?;
    pre.set_deps.reserve(n);
    for _ in 0..n {
        pre.set_deps.push(SetDep {
            src_csid: SetId(r_u64(&mut r)?),
            dst_csid: SetId(r_u64(&mut r)?),
        });
    }
    let n = r_u64(&mut r).context("read cc_of count")?;
    let n = checked_count(n, 16, file_len, "cc_of")?;
    pre.cc_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cc_of.insert(k, v);
    }
    let n = r_u64(&mut r).context("read cs_of count")?;
    let n = checked_count(n, 16, file_len, "cs_of")?;
    pre.cs_of = FxHashMap::with_capacity_and_hasher(n, Default::default());
    for _ in 0..n {
        let k = r_u64(&mut r)?;
        let v = r_u64(&mut r)?;
        pre.cs_of.insert(k, v);
    }
    let n = r_u64(&mut r).context("read large_components count")?;
    let n = checked_count(n, 24, file_len, "large_components")?;
    pre.large_components.reserve(n);
    for _ in 0..n {
        let cc = r_u64(&mut r)?;
        let nodes = r_u64(&mut r)? as usize;
        let edges = r_u64(&mut r)? as usize;
        pre.large_components.push((cc, nodes, edges));
    }
    pre.component_count = r_u64(&mut r).context("read component_count")? as usize;
    pre.set_count = r_u64(&mut r).context("read set_count")? as usize;
    Ok(pre)
}

/// [`save_trace`] through a temp file + atomic rename: an interrupted
/// write never destroys an existing file at `path`. This is what the CLI
/// `ingest` subcommand persists with — it updates its own inputs in place,
/// so a mid-write crash must not lose the only copy of the index.
pub fn save_trace_atomic(path: &Path, trace: &Trace) -> Result<()> {
    save_atomic(path, |tmp| save_trace(tmp, trace))
}

/// [`save_preprocessed`] through a temp file + atomic rename (see
/// [`save_trace_atomic`]).
pub fn save_preprocessed_atomic(path: &Path, pre: &Preprocessed) -> Result<()> {
    save_atomic(path, |tmp| save_preprocessed(tmp, pre))
}

/// `EXDEV` — "invalid cross-device link" — on Linux, macOS and the BSDs.
/// `rename(2)` returns it when source and destination are on different
/// filesystems, where an atomic move is impossible.
const EXDEV: i32 = 18;

fn save_atomic(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    write(&tmp)?;
    // fsync the temp file before the rename: without it a crash shortly
    // after the rename can leave the *new* name pointing at unflushed (and
    // therefore possibly empty/truncated) data — losing the only copy the
    // rename was supposed to protect.
    std::fs::File::open(&tmp)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsyncing {tmp:?} before the atomic rename"))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let crosses_fs = e.raw_os_error() == Some(EXDEV);
        let _ = std::fs::remove_file(&tmp);
        if crosses_fs {
            // The temp file sits next to the destination, so this needs an
            // exotic layout (e.g. a mount point or cross-device symlink at
            // the destination path) — but when it happens, the failure mode
            // deserves a precise name rather than a generic rename error.
            bail!(
                "cannot atomically move {tmp:?} into place at {path:?}: rename(2) \
                 reported EXDEV (the two paths resolve to different filesystems, so \
                 an atomic replace is impossible there)"
            );
        }
        return Err(anyhow::Error::new(e)
            .context(format!("moving {tmp:?} into place at {path:?}")));
    }
    // Durability of the *rename* needs a directory fsync; best-effort (not
    // every filesystem supports fsync on a directory handle) — the data
    // itself was already synced above.
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// CSV export of a trace (`src,dst,op`) for external inspection.
pub fn export_csv(path: &Path, trace: &Trace) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "src,dst,op")?;
    for t in &trace.triples {
        writeln!(w, "{},{},{}", t.src.raw(), t.dst.raw(), t.op.0)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("provspark_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn trace_roundtrip() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let p = tmp("trace.bin");
        save_trace(&p, &trace).unwrap();
        let loaded = load_trace(&p).unwrap();
        assert_eq!(trace.triples, loaded.triples);
    }

    #[test]
    fn preprocessed_roundtrip() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let p = tmp("pre.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(pre.cc_triples, loaded.cc_triples);
        assert_eq!(pre.cs_triples, loaded.cs_triples);
        assert_eq!(pre.set_deps, loaded.set_deps);
        assert_eq!(pre.cc_of, loaded.cc_of);
        assert_eq!(pre.cs_of, loaded.cs_of);
        assert_eq!(pre.large_components, loaded.large_components);
        assert_eq!(pre.component_count, loaded.component_count);
        assert_eq!(pre.set_count, loaded.set_count);
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("bogus.bin");
        std::fs::write(&p, b"NOTMAGIC123").unwrap();
        assert!(load_trace(&p).is_err());
        assert!(load_preprocessed(&p).is_err());
    }

    #[test]
    fn atomic_save_replaces_and_leaves_no_temp() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let tp = tmp("atomic_trace.bin");
        let pp = tmp("atomic_pre.bin");
        // Seed the destination with garbage an interrupted write must not
        // be able to leave behind.
        std::fs::write(&tp, b"GARBAGE").unwrap();
        save_trace_atomic(&tp, &trace).unwrap();
        save_preprocessed_atomic(&pp, &pre).unwrap();
        assert_eq!(load_trace(&tp).unwrap().triples, trace.triples);
        assert_eq!(load_preprocessed(&pp).unwrap().epoch, pre.epoch);
        for p in [&tp, &pp] {
            let mut t = p.as_os_str().to_owned();
            t.push(".tmp");
            assert!(!std::path::PathBuf::from(t).exists(), "temp file left behind");
        }
    }

    #[test]
    fn roundtrip_preserves_incremental_epoch_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 7; // as if 7 batches were ingested
        assert_eq!(pre.theta, 200);
        let p = tmp("pre_epoch.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 200);
        assert_eq!(loaded.big_threshold, 100);
        assert_eq!(loaded.epoch, 7);
        // …alongside everything the query engines need.
        assert_eq!(pre.cc_triples, loaded.cc_triples);
        assert_eq!(pre.cs_of, loaded.cs_of);
    }

    #[test]
    fn v3_roundtrip_preserves_fingerprint_and_shard_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        assert_ne!(pre.workflow_fingerprint, 0, "preprocess records the workflow");
        pre.shard_index = 2;
        pre.shard_count = 4;
        let p = tmp("pre_v3.bin");
        save_preprocessed(&p, &pre).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.workflow_fingerprint, pre.workflow_fingerprint);
        assert_eq!(loaded.shard_index, 2);
        assert_eq!(loaded.shard_count, 4);
        assert_eq!(loaded.cc_triples, pre.cc_triples);
        assert_eq!(loaded.cs_triples, pre.cs_triples);
    }

    /// The exact v2 (`PSPKPRE2`) layout as PR 3 wrote it — a regression
    /// fixture for backwards compatibility, kept in sync with nothing (that
    /// is the point: old files must keep loading verbatim).
    fn save_preprocessed_v2(path: &std::path::Path, pre: &Preprocessed) {
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(b"PSPKPRE2").unwrap();
        w_u64(&mut w, pre.theta as u64).unwrap();
        w_u64(&mut w, pre.big_threshold as u64).unwrap();
        w_u64(&mut w, pre.epoch).unwrap();
        write_sections(&mut w, pre).unwrap();
        w.flush().unwrap();
    }

    #[test]
    fn v2_file_loads_with_zeroed_fingerprint_and_shard_fields() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.epoch = 3;
        let p = tmp("pre_v2.bin");
        save_preprocessed_v2(&p, &pre);
        let loaded = load_preprocessed(&p).unwrap();
        // v2 header fields survive…
        assert_eq!(loaded.theta, 200);
        assert_eq!(loaded.big_threshold, 100);
        assert_eq!(loaded.epoch, 3);
        // …the v3 additions load as "unrecorded"…
        assert_eq!(loaded.workflow_fingerprint, 0, "v2 has no recorded workflow");
        assert_eq!(loaded.shard_index, 0);
        assert_eq!(loaded.shard_count, 0);
        // …and the body is intact.
        assert_eq!(loaded.cc_triples, pre.cc_triples);
        assert_eq!(loaded.cs_triples, pre.cs_triples);
        assert_eq!(loaded.cc_of, pre.cc_of);
        assert_eq!(loaded.cs_of, pre.cs_of);
        assert_eq!(loaded.set_deps, pre.set_deps);
        assert_eq!(loaded.large_components, pre.large_components);
        assert_eq!(loaded.component_count, pre.component_count);
        assert_eq!(loaded.set_count, pre.set_count);
    }

    #[test]
    fn legacy_v1_file_loads_with_zeroed_epoch_fields() {
        // A minimal empty v1 file: old magic + the 8 zero section counts
        // (cc, cs, deps, cc_of, cs_of, large, component_count, set_count).
        let p = tmp("pre_v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE1");
        bytes.extend_from_slice(&[0u8; 8 * 8]);
        std::fs::write(&p, bytes).unwrap();
        let loaded = load_preprocessed(&p).unwrap();
        assert_eq!(loaded.theta, 0, "v1 has no recorded θ");
        assert_eq!(loaded.epoch, 0);
        assert_eq!(loaded.workflow_fingerprint, 0);
        assert_eq!(loaded.shard_count, 0);
        assert!(loaded.cc_triples.is_empty());
    }

    #[test]
    fn load_errors_name_the_offending_path() {
        let missing = tmp("definitely_missing.bin");
        let _ = std::fs::remove_file(&missing);
        for err in [
            format!("{:#}", load_trace(&missing).unwrap_err()),
            format!("{:#}", load_preprocessed(&missing).unwrap_err()),
        ] {
            assert!(
                err.contains("definitely_missing.bin"),
                "error must name the path: {err}"
            );
        }
        // Truncated file: magic only, sections missing.
        let p = tmp("truncated.bin");
        std::fs::write(&p, b"PSPKPRE2").unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(err.contains("truncated.bin"), "error must name the path: {err}");
    }

    /// A flipped bit in a count field must come back as a named error, not
    /// an allocation-failure abort: every count is validated against the
    /// file's actual size before it sizes a `Vec`/map.
    #[test]
    fn implausible_counts_are_errors_not_aborts() {
        // Trace whose header claims u64::MAX triples in a 16-byte body.
        let p = tmp("huge_trace_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKTRC1");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        assert!(
            err.contains("huge_trace_count.bin") && err.contains("implausible"),
            "expected a named implausible-count error: {err}"
        );

        // Preprocessed v3 whose first section count is u64::MAX.
        let p = tmp("huge_pre_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 6 * 8]); // zeroed v3 header
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(
            err.contains("huge_pre_count.bin")
                && err.contains("cc_triples")
                && err.contains("implausible"),
            "expected a named implausible-count error: {err}"
        );
    }

    #[test]
    fn short_header_and_truncated_body_name_the_path() {
        // v3 header cut off after two of the six fields.
        let p = tmp("short_header.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 2 * 8]);
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(
            err.contains("short_header.bin") && err.contains("epoch"),
            "expected the missing header field to be named: {err}"
        );

        // Plausible count (2 cc_triples would fit in the file if the header
        // were honest about the rest) but the records themselves are absent.
        let p = tmp("truncated_body.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKPRE3");
        bytes.extend_from_slice(&[0u8; 6 * 8]);
        bytes.extend_from_slice(&2u64.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_preprocessed(&p).unwrap_err());
        assert!(err.contains("truncated_body.bin"), "error must name the path: {err}");

        // Trace truncated mid-record.
        let p = tmp("truncated_trace.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKTRC1");
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 10]); // half a 20-byte triple record
        std::fs::write(&p, bytes).unwrap();
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        assert!(err.contains("truncated_trace.bin"), "error must name the path: {err}");
    }

    #[test]
    fn injected_store_io_faults_surface_as_errors() {
        use crate::fault::{install_io_faults, FaultInjector, FaultPlan};
        use std::sync::Arc;
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let p = tmp("faulted_store.bin");
        save_trace(&p, &trace).unwrap();
        let plan: FaultPlan = "io:store:1.0,seed=4".parse().unwrap();
        install_io_faults(Some(Arc::new(FaultInjector::new(plan))));
        let err = format!("{:#}", load_trace(&p).unwrap_err());
        install_io_faults(None);
        assert!(err.contains("injected"), "expected the injected fault: {err}");
        assert_eq!(load_trace(&p).unwrap().triples, trace.triples);
    }

    #[test]
    fn csv_export_has_header_and_rows() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
        let p = tmp("trace.csv");
        export_csv(&p, &trace).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("src,dst,op\n"));
        assert_eq!(text.lines().count(), trace.len() + 1);
    }
}
