//! Write-ahead journaling for multi-step state mutations.
//!
//! Two cooperating pieces:
//!
//! * [`MigrationJournal`] — records a sharded-ingest migration plan
//!   *before* any shard mutates, then commits each step as it lands. Every
//!   step is all-or-nothing at the [`ProvSession`] layer (a failed
//!   `ingest`/`replace_state` leaves the served epoch untouched), so the
//!   journal cursor is an exact resume point: `ShardedSession::recover`
//!   re-runs the plan from the first uncommitted step and converges to the
//!   same final state the uninterrupted ingest would have reached. The
//!   journal lives in memory and, when a path is configured, mirrors to a
//!   human-readable file — a crashed *process* leaves that file behind as
//!   evidence the batch never fully applied (the CLI reports it and rolls
//!   back on startup: stored state is always the pre-batch state, because
//!   stores are only rewritten after a batch completes).
//!
//! * [`commit_files`] / [`recover_commit`] — a two-phase publish for the
//!   store files themselves. The CLI persists trace + index as *two* files;
//!   two bare renames leave a crash window where one file is new and the
//!   other old. Instead, every file is staged (`<final>.staged`, fsynced),
//!   a journal naming the publish set is fsynced, and only then are the
//!   staged files renamed over the finals. On startup, [`recover_commit`]
//!   rolls an interrupted publish forward (journal present ⇒ staging was
//!   complete) or discards orphaned staged files (no journal ⇒ the publish
//!   never became durable).
//!
//! All file operations probe the thread-local fault injector at
//! [`FaultSite::Journal`] (see [`crate::fault::io_probe`]), so crash
//! recovery is testable by injection.
//!
//! [`ProvSession`]: crate::harness::ProvSession

use crate::fault::{io_probe, FaultSite};
use anyhow::{bail, ensure, Context, Result};
use std::fs::{self, File, OpenOptions};
use std::hash::Hasher;
use std::io::Write;
use std::path::{Path, PathBuf};

/// First line of every journal file.
pub const JOURNAL_MAGIC: &str = "PSPKJRNL1";

/// Deterministic fingerprint of a step plan (content-addresses the plan so
/// a resumed journal can be checked against the plan it was written for).
pub fn plan_fingerprint(steps: &[String]) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    for s in steps {
        h.write(s.as_bytes());
        h.write_u8(0xff);
    }
    h.finish()
}

/// The write-ahead record of one sharded-ingest migration plan: the full
/// step list written up front, plus a commit cursor advanced as steps land.
#[derive(Debug)]
pub struct MigrationJournal {
    fingerprint: u64,
    steps: Vec<String>,
    done: usize,
    path: Option<PathBuf>,
}

impl MigrationJournal {
    /// Start a journal for `steps`, durably recording the whole plan (when
    /// `path` is given) before the caller mutates anything.
    pub fn begin(steps: Vec<String>, path: Option<&Path>) -> Result<Self> {
        let fingerprint = plan_fingerprint(&steps);
        let j = Self { fingerprint, steps, done: 0, path: path.map(Path::to_path_buf) };
        if let Some(p) = &j.path {
            io_probe(FaultSite::Journal)?;
            let mut body = format!("{JOURNAL_MAGIC}\nfingerprint {fingerprint:016x}\n");
            for (i, s) in j.steps.iter().enumerate() {
                body.push_str(&format!("step {i} {s}\n"));
            }
            write_sync(p, body.as_bytes())
                .with_context(|| format!("writing migration journal {}", p.display()))?;
        }
        Ok(j)
    }

    /// Parse a journal file left by an interrupted run. `Ok(None)` when no
    /// file exists (the common, clean case).
    pub fn load(path: &Path) -> Result<Option<Self>> {
        io_probe(FaultSite::Journal)?;
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading migration journal {}", path.display()))
            }
        };
        let mut lines = text.lines();
        ensure!(
            lines.next() == Some(JOURNAL_MAGIC),
            "migration journal {} has a bad magic line (not a {JOURNAL_MAGIC} file)",
            path.display()
        );
        let fp_line = lines
            .next()
            .with_context(|| format!("migration journal {} is truncated", path.display()))?;
        let fingerprint = fp_line
            .strip_prefix("fingerprint ")
            .and_then(|h| u64::from_str_radix(h, 16).ok())
            .with_context(|| {
                format!("migration journal {}: bad fingerprint line {fp_line:?}", path.display())
            })?;
        let mut steps = Vec::new();
        let mut done = 0usize;
        for line in lines {
            if let Some(rest) = line.strip_prefix("step ") {
                let (idx, desc) = rest.split_once(' ').with_context(|| {
                    format!("migration journal {}: bad step line {line:?}", path.display())
                })?;
                ensure!(
                    idx.parse::<usize>().ok() == Some(steps.len()),
                    "migration journal {}: step lines out of order at {line:?}",
                    path.display()
                );
                steps.push(desc.to_string());
            } else if let Some(idx) = line.strip_prefix("commit ") {
                ensure!(
                    idx.parse::<usize>().ok() == Some(done),
                    "migration journal {}: commit lines out of order at {line:?}",
                    path.display()
                );
                done += 1;
            } else if !line.is_empty() {
                bail!("migration journal {}: unrecognized line {line:?}", path.display());
            }
        }
        ensure!(
            done <= steps.len(),
            "migration journal {}: more commits than steps",
            path.display()
        );
        Ok(Some(Self { fingerprint, steps, done, path: Some(path.to_path_buf()) }))
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn steps(&self) -> &[String] {
        &self.steps
    }

    /// Index of the first step not yet committed — where execution resumes.
    pub fn cursor(&self) -> usize {
        self.done
    }

    pub fn is_complete(&self) -> bool {
        self.done >= self.steps.len()
    }

    /// Commit the step at the cursor. The in-memory cursor advances even if
    /// the durable append then fails (the step *did* land; in-process
    /// recovery must not re-run it — the stale file only ever under-counts,
    /// and the CLI's startup path treats any leftover journal as a
    /// rolled-back batch anyway).
    pub fn mark_done(&mut self) -> Result<()> {
        ensure!(!self.is_complete(), "journal already complete");
        let i = self.done;
        self.done += 1;
        if let Some(p) = &self.path {
            io_probe(FaultSite::Journal)?;
            append_sync(p, format!("commit {i}\n").as_bytes())
                .with_context(|| format!("committing step {i} to {}", p.display()))?;
        }
        Ok(())
    }

    /// All steps landed: retire the journal (removes the file, if any).
    pub fn finish(self) -> Result<()> {
        ensure!(self.is_complete(), "journal finished with uncommitted steps");
        if let Some(p) = &self.path {
            io_probe(FaultSite::Journal)?;
            fs::remove_file(p)
                .with_context(|| format!("removing migration journal {}", p.display()))?;
        }
        Ok(())
    }
}

/// What [`recover_commit`] found and did on startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitRecovery {
    /// No interrupted publish.
    Clean,
    /// A publish journal existed: staging was complete, so the remaining
    /// staged files were renamed into place (count given).
    RolledForward(usize),
    /// Orphaned staged files with no journal: the publish never became
    /// durable, so they were discarded (count given).
    RolledBack(usize),
}

/// The staging sibling of a final path (`<final>.staged`).
pub fn staged_path(final_path: &Path) -> PathBuf {
    let mut os = final_path.as_os_str().to_os_string();
    os.push(".staged");
    PathBuf::from(os)
}

/// Atomically publish a set of already-staged files: the caller has written
/// every `staged_path(final)`; this fsyncs a journal naming the set, renames
/// each staged file over its final path, then retires the journal. A crash
/// at any point is recoverable by [`recover_commit`]: before the journal is
/// durable nothing is published (staged files are discarded); after it, the
/// whole set is rolled forward.
pub fn commit_files(journal_path: &Path, finals: &[PathBuf]) -> Result<()> {
    io_probe(FaultSite::Journal)?;
    for f in finals {
        let s = staged_path(f);
        ensure!(s.exists(), "staged file {} missing before publish", s.display());
    }
    let mut body = format!("{JOURNAL_MAGIC}\n");
    for f in finals {
        body.push_str(&format!("publish {}\n", f.display()));
    }
    write_sync(journal_path, body.as_bytes())
        .with_context(|| format!("writing publish journal {}", journal_path.display()))?;
    for f in finals {
        io_probe(FaultSite::Journal)?;
        fs::rename(staged_path(f), f)
            .with_context(|| format!("publishing {}", f.display()))?;
    }
    fs::remove_file(journal_path)
        .with_context(|| format!("removing publish journal {}", journal_path.display()))?;
    Ok(())
}

/// Startup recovery for [`commit_files`]: roll an interrupted publish
/// forward (journal present) or discard orphaned staged files (no journal).
/// `finals` is the full set of store paths this process publishes — used to
/// find orphans; the roll-forward set comes from the journal itself.
pub fn recover_commit(journal_path: &Path, finals: &[PathBuf]) -> Result<CommitRecovery> {
    io_probe(FaultSite::Journal)?;
    match fs::read_to_string(journal_path) {
        Ok(text) => {
            let mut lines = text.lines();
            ensure!(
                lines.next() == Some(JOURNAL_MAGIC),
                "publish journal {} has a bad magic line",
                journal_path.display()
            );
            let mut moved = 0usize;
            for line in lines {
                let Some(f) = line.strip_prefix("publish ") else {
                    if line.is_empty() {
                        continue;
                    }
                    bail!(
                        "publish journal {}: unrecognized line {line:?}",
                        journal_path.display()
                    );
                };
                let f = PathBuf::from(f);
                let s = staged_path(&f);
                if s.exists() {
                    fs::rename(&s, &f)
                        .with_context(|| format!("rolling forward {}", f.display()))?;
                    moved += 1;
                }
                // Staged file gone + journal present: this file was already
                // renamed before the crash — nothing to do.
            }
            fs::remove_file(journal_path)
                .with_context(|| format!("removing publish journal {}", journal_path.display()))?;
            Ok(CommitRecovery::RolledForward(moved))
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            let mut dropped = 0usize;
            for f in finals {
                let s = staged_path(f);
                if s.exists() {
                    fs::remove_file(&s)
                        .with_context(|| format!("discarding orphaned {}", s.display()))?;
                    dropped += 1;
                }
            }
            Ok(if dropped > 0 {
                CommitRecovery::RolledBack(dropped)
            } else {
                CommitRecovery::Clean
            })
        }
        Err(e) => Err(e)
            .with_context(|| format!("reading publish journal {}", journal_path.display())),
    }
}

fn write_sync(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(bytes)?;
    f.sync_all()
}

fn append_sync(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = OpenOptions::new().append(true).open(path)?;
    f.write_all(bytes)?;
    f.sync_all()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("provspark-journal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn journal_round_trips_through_its_file() {
        let d = tmpdir("roundtrip");
        let p = d.join("migration.journal");
        let steps = vec!["ingest shard 1".to_string(), "replace shard 0".to_string()];
        let mut j = MigrationJournal::begin(steps.clone(), Some(&p)).unwrap();
        assert_eq!(j.cursor(), 0);
        j.mark_done().unwrap();

        let loaded = MigrationJournal::load(&p).unwrap().expect("file exists");
        assert_eq!(loaded.steps(), &steps[..]);
        assert_eq!(loaded.cursor(), 1);
        assert!(!loaded.is_complete());
        assert_eq!(loaded.fingerprint(), plan_fingerprint(&steps));

        j.mark_done().unwrap();
        assert!(j.is_complete());
        j.finish().unwrap();
        assert!(MigrationJournal::load(&p).unwrap().is_none(), "finish removes the file");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_journal_files_error_with_the_path() {
        let d = tmpdir("corrupt");
        for (name, body) in [
            ("bad-magic", "NOTAJRNL\n"),
            ("truncated", "PSPKJRNL1\n"),
            ("bad-step-order", "PSPKJRNL1\nfingerprint 0\nstep 1 x\n"),
            ("bad-commit", "PSPKJRNL1\nfingerprint 0\nstep 0 x\ncommit 5\n"),
            ("garbage", "PSPKJRNL1\nfingerprint 0\nwat\n"),
        ] {
            let p = d.join(name);
            fs::write(&p, body).unwrap();
            let err = MigrationJournal::load(&p).unwrap_err();
            assert!(
                format!("{err:#}").contains(name),
                "error for {name} names the path: {err:#}"
            );
        }
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn publish_rolls_forward_and_back() {
        let d = tmpdir("publish");
        let finals = vec![d.join("trace.bin"), d.join("pre.bin")];
        let journal = d.join("publish.journal");
        for f in &finals {
            fs::write(f, b"old").unwrap();
            fs::write(staged_path(f), b"new").unwrap();
        }

        // Clean publish.
        commit_files(&journal, &finals).unwrap();
        assert!(!journal.exists());
        for f in &finals {
            assert_eq!(fs::read(f).unwrap(), b"new");
            assert!(!staged_path(f).exists());
        }
        assert_eq!(recover_commit(&journal, &finals).unwrap(), CommitRecovery::Clean);

        // Crash after the journal + one rename: roll forward.
        fs::write(staged_path(&finals[1]), b"v2").unwrap();
        fs::write(
            &journal,
            format!(
                "{JOURNAL_MAGIC}\npublish {}\npublish {}\n",
                finals[0].display(),
                finals[1].display()
            ),
        )
        .unwrap();
        assert_eq!(
            recover_commit(&journal, &finals).unwrap(),
            CommitRecovery::RolledForward(1)
        );
        assert!(!journal.exists());
        assert_eq!(fs::read(&finals[1]).unwrap(), b"v2");

        // Crash before the journal: staged orphans are discarded.
        fs::write(staged_path(&finals[0]), b"half").unwrap();
        assert_eq!(
            recover_commit(&journal, &finals).unwrap(),
            CommitRecovery::RolledBack(1)
        );
        assert!(!staged_path(&finals[0]).exists());
        assert_eq!(fs::read(&finals[0]).unwrap(), b"new", "final untouched by rollback");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn injected_journal_io_faults_surface_as_errors() {
        use crate::fault::{install_io_faults, FaultInjector};
        use std::sync::Arc;
        let d = tmpdir("faults");
        let p = d.join("migration.journal");
        // Every journal IO probe fails.
        let inj =
            Arc::new(FaultInjector::new("io:journal:1.0,seed=3".parse().unwrap()));
        install_io_faults(Some(inj));
        let err = MigrationJournal::begin(vec!["x".into()], Some(&p)).unwrap_err();
        assert!(format!("{err:#}").contains("journal"), "{err:#}");
        install_io_faults(None);
        // Without the injector the same call succeeds.
        MigrationJournal::begin(vec!["x".into()], Some(&p)).unwrap();
        let _ = fs::remove_dir_all(&d);
    }
}
