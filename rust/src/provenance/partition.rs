//! Algorithm 3: partitioning large components into weakly connected sets,
//! guided by the workflow dependency graph's splits (paper §3).
//!
//! For each split `sp`, the subgraph `G[V(sp, c)]` induced inside component
//! `c` by the split's entities is decomposed into weakly connected
//! components; any piece with ≥ θ nodes recurses with sub-splits. The
//! resulting sets satisfy the paper's criteria:
//!
//! * **C1** (few set-dependencies): two sets produced by the same
//!   `(split, component)` pass are disconnected within that split by
//!   construction, so they never contribute a dependency to each other.
//! * **C2** (small set-lineage): splits are weakly connected table sets, so
//!   a value's immediate ancestors tend to fall in its own set.
//! * **C3** (small sets): the θ recursion bounds set sizes wherever the
//!   dependency graph can still be subdivided.

use crate::provenance::model::ProvTriple;
use crate::util::ids::EntityId;
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::{Split, SplitSet};
use rustc_hash::{FxHashMap, FxHashSet};

/// Statistics for one `(component, split)` pass — the rows of Table 9.
#[derive(Debug, Clone)]
pub struct PassStats {
    /// Caller-assigned component label (e.g. "LC1", "LC2_lc1").
    pub component: String,
    pub split: String,
    /// |W(sp, c)| — number of weakly connected sets produced.
    pub sets: usize,
    /// Sets with ≥ `big_threshold` nodes (paper uses 1000).
    pub big_sets: usize,
    /// Node count of the largest set.
    pub largest: usize,
}

/// Algorithm 3 driver.
pub struct Partitioner<'a> {
    pub graph: &'a DependencyGraph,
    pub splits: &'a SplitSet,
    /// θ — recurse on split-components with at least this many nodes.
    pub theta: usize,
    /// Threshold for the `big_sets` statistic (paper: 1000; scale with the
    /// generator's divisor).
    pub big_threshold: usize,
}

impl<'a> Partitioner<'a> {
    /// Partition one large component.
    ///
    /// * `triples` — the component's provenance triples.
    /// * `label` — component label for statistics (e.g. "LC1").
    ///
    /// Returns the weakly connected sets (as node lists) plus per-pass
    /// statistics. Every node of the component lands in exactly one set.
    ///
    /// Perf note (EXPERIMENTS.md §Perf, L3-2): the component is remapped to
    /// dense indices once; all union-finds and membership checks then run
    /// over flat `Vec`s instead of `u64` hash maps.
    pub fn partition_component(
        &self,
        triples: &[ProvTriple],
        label: &str,
    ) -> (Vec<Vec<u64>>, Vec<PassStats>) {
        // Dense remap of the component's nodes.
        let mut raw_of: Vec<u64> = Vec::with_capacity(triples.len() * 2);
        for t in triples {
            raw_of.push(t.src.raw());
            raw_of.push(t.dst.raw());
        }
        raw_of.sort_unstable();
        raw_of.dedup();
        let dense_of: FxHashMap<u64, u32> =
            raw_of.iter().enumerate().map(|(i, &r)| (r, i as u32)).collect();
        let ents: Vec<u16> = raw_of
            .iter()
            .map(|&r| crate::util::ids::AttrValueId(r).entity().0)
            .collect();
        let edges: Vec<(u32, u32)> = triples
            .iter()
            .map(|t| (dense_of[&t.src.raw()], dense_of[&t.dst.raw()]))
            .collect();
        let all_nodes: Vec<u32> = (0..raw_of.len() as u32).collect();

        let mut sets = Vec::new();
        let mut stats = Vec::new();
        let mut scratch = Scratch::new(raw_of.len());
        self.recurse(
            &edges,
            &all_nodes,
            &ents,
            self.splits.top_level(),
            label,
            &mut scratch,
            &mut sets,
            &mut stats,
        );
        let sets = sets
            .into_iter()
            .map(|s: Vec<u32>| s.into_iter().map(|i| raw_of[i as usize]).collect())
            .collect();
        (sets, stats)
    }

    #[allow(clippy::too_many_arguments)]
    fn recurse(
        &self,
        edges: &[(u32, u32)],
        nodes: &[u32],
        ents: &[u16],
        splits: &[Split],
        label: &str,
        scratch: &mut Scratch,
        out_sets: &mut Vec<Vec<u32>>,
        out_stats: &mut Vec<PassStats>,
    ) {
        for sp in splits {
            // Entity membership mask for this split.
            let mut in_split = vec![false; self.graph.entity_count()];
            for &e in sp.entities() {
                in_split[e.0 as usize] = true;
            }

            // V(sp, c) and G[V(sp, c)]: union-find over intra-split edges.
            let split_nodes: Vec<u32> = nodes
                .iter()
                .copied()
                .filter(|&i| in_split[ents[i as usize] as usize])
                .collect();
            if split_nodes.is_empty() {
                continue; // split has no vertices inside this component
            }
            for &i in &split_nodes {
                scratch.parent[i as usize] = i;
            }
            for &(s, d) in edges {
                if in_split[ents[s as usize] as usize] && in_split[ents[d as usize] as usize] {
                    scratch.union(s, d);
                }
            }

            // W(sp, c): group nodes by root.
            let mut comps: FxHashMap<u32, Vec<u32>> = FxHashMap::default();
            for &i in &split_nodes {
                comps.entry(scratch.find(i)).or_default().push(i);
            }
            // Reset scratch for the next pass (only the touched slots).
            for &i in &split_nodes {
                scratch.parent[i as usize] = u32::MAX;
                scratch.rank[i as usize] = 0;
            }

            let mut pass = PassStats {
                component: label.to_string(),
                split: sp.name().to_string(),
                sets: 0,
                big_sets: 0,
                largest: 0,
            };
            let mut oversized: Vec<Vec<u32>> = Vec::new();
            for (_, cn) in comps {
                pass.sets += 1;
                pass.largest = pass.largest.max(cn.len());
                if cn.len() >= self.big_threshold {
                    pass.big_sets += 1;
                }
                if cn.len() >= self.theta {
                    oversized.push(cn);
                } else {
                    out_sets.push(cn);
                }
            }
            out_stats.push(pass);

            // Recurse on oversized split-components with sub-splits.
            if oversized.is_empty() {
                continue;
            }
            match self.splits.get_sub_splits(self.graph, sp) {
                Some(sub) => {
                    for (i, cn) in oversized.into_iter().enumerate() {
                        for &n in &cn {
                            scratch.member[n as usize] = true;
                        }
                        let cn_edges: Vec<(u32, u32)> = edges
                            .iter()
                            .copied()
                            .filter(|&(s, d)| {
                                scratch.member[s as usize] && scratch.member[d as usize]
                            })
                            .collect();
                        for &n in &cn {
                            scratch.member[n as usize] = false;
                        }
                        let sub_label = format!("{label}_{}lc{}", sp.name(), i + 1);
                        self.recurse(
                            &cn_edges, &cn, ents, &sub, &sub_label, scratch, out_sets, out_stats,
                        );
                    }
                }
                None => {
                    // Single-entity split: cannot subdivide further; keep
                    // the oversized sets (paper's irreducible case).
                    out_sets.extend(oversized);
                }
            }
        }
    }
}

/// Reusable dense union-find scratch space. `parent[i] == u32::MAX` marks
/// "not in the current pass".
struct Scratch {
    parent: Vec<u32>,
    rank: Vec<u8>,
    member: Vec<bool>,
}

impl Scratch {
    fn new(n: usize) -> Self {
        Self { parent: vec![u32::MAX; n], rank: vec![0; n], member: vec![false; n] }
    }

    #[inline]
    fn find(&mut self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
    }

    #[inline]
    fn union(&mut self, a: u32, b: u32) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return;
        }
        let (ka, kb) = (self.rank[ra as usize], self.rank[rb as usize]);
        if ka < kb {
            self.parent[ra as usize] = rb;
        } else if ka > kb {
            self.parent[rb as usize] = ra;
        } else {
            self.parent[rb as usize] = ra;
            self.rank[ra as usize] = ka + 1;
        }
    }
}

/// True when `set` is weakly connected within the subgraph induced by the
/// split's entities (test helper for the Algorithm 3 invariant).
pub fn is_weakly_connected_within(
    triples: &[ProvTriple],
    set: &[u64],
    split_entities: &[EntityId],
) -> bool {
    if set.len() <= 1 {
        return true;
    }
    let members: FxHashSet<u64> = set.iter().copied().collect();
    let ents: FxHashSet<u16> = split_entities.iter().map(|e| e.0).collect();
    let in_sub = |raw: u64| {
        members.contains(&raw) && ents.contains(&crate::util::ids::AttrValueId(raw).entity().0)
    };
    let mut adj: FxHashMap<u64, Vec<u64>> = FxHashMap::default();
    for t in triples {
        let (s, d) = (t.src.raw(), t.dst.raw());
        if in_sub(s) && in_sub(d) {
            adj.entry(s).or_default().push(d);
            adj.entry(d).or_default().push(s);
        }
    }
    let mut seen: FxHashSet<u64> = FxHashSet::default();
    let mut stack = vec![set[0]];
    seen.insert(set[0]);
    while let Some(u) = stack.pop() {
        for &v in adj.get(&u).into_iter().flatten() {
            if seen.insert(v) {
                stack.push(v);
            }
        }
    }
    seen.len() == set.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::AttrValueId;
    use crate::workflow::curation::text_curation_workflow;

    fn av(g: &DependencyGraph, name: &str, s: u64) -> AttrValueId {
        AttrValueId::new(g.entity_by_name(name).unwrap(), s)
    }

    fn t(g: &DependencyGraph, pe: &str, ps: u64, ce: &str, cs: u64) -> ProvTriple {
        let src = av(g, pe, ps);
        let dst = av(g, ce, cs);
        let op = g.op_between(src.entity(), dst.entity()).unwrap();
        ProvTriple::new(src, dst, op)
    }

    /// Small cross-split component:
    ///   TOKS:1 → ANNOTS:1 → METSPANS:1 → F10WMTR:1 → CANDS:1 → RESOLVED:1
    ///   TOKS:2 → ANNOTS:1 (same sp1 chain via SENTS:1 → TOKS:1/2)
    fn small_component(g: &DependencyGraph) -> Vec<ProvTriple> {
        vec![
            t(g, "SENTS", 1, "TOKS", 1),
            t(g, "SENTS", 1, "TOKS", 2),
            t(g, "TOKS", 1, "ANNOTS", 1),
            t(g, "TOKS", 2, "ANNOTS", 1),
            t(g, "ANNOTS", 1, "METSPANS", 1),
            t(g, "METSPANS", 1, "F10WMTR", 1),
            t(g, "F10WMTR", 1, "CANDS", 1),
            t(g, "CANDS", 1, "RESOLVED", 1),
        ]
    }

    #[test]
    fn partitions_cover_nodes_disjointly() {
        let (g, splits) = text_curation_workflow();
        let triples = small_component(&g);
        let p = Partitioner { graph: &g, splits: &splits, theta: 1000, big_threshold: 1000 };
        let (sets, stats) = p.partition_component(&triples, "c0");
        let mut seen = FxHashSet::default();
        let mut total = 0;
        for s in &sets {
            for &n in s {
                assert!(seen.insert(n), "node {n} in two sets");
                total += 1;
            }
        }
        // Nodes: SENTS:1, TOKS:1, TOKS:2 (sp1) + ANNOTS:1, METSPANS:1,
        // F10WMTR:1, CANDS:1 (sp2) + RESOLVED:1 (sp3) = 8.
        assert_eq!(total, 8);
        // One pass per split touched.
        assert_eq!(stats.len(), 3);
    }

    #[test]
    fn sets_respect_split_boundaries() {
        let (g, splits) = text_curation_workflow();
        let triples = small_component(&g);
        let p = Partitioner { graph: &g, splits: &splits, theta: 1000, big_threshold: 1000 };
        let (sets, _) = p.partition_component(&triples, "c0");
        for s in &sets {
            let names: FxHashSet<&str> = s
                .iter()
                .map(|&n| splits.split_of(AttrValueId(n).entity()).unwrap())
                .collect();
            assert_eq!(names.len(), 1, "set crosses splits: {s:?}");
        }
    }

    #[test]
    fn theta_forces_recursion() {
        let (g, splits) = text_curation_workflow();
        // Build a chain inside sp3 crossing sp4/sp5:
        // RESOLVED → MTRCS → MTRVALS → KBROWS → KBATTRS → RPTROWS
        let triples = vec![
            t(&g, "RESOLVED", 1, "MTRCS", 1),
            t(&g, "MTRCS", 1, "MTRVALS", 1),
            t(&g, "MTRVALS", 1, "KBROWS", 1),
            t(&g, "KBROWS", 1, "KBATTRS", 1),
            t(&g, "KBATTRS", 1, "RPTROWS", 1),
        ];
        // θ=2: the 6-node sp3 component must recurse into sp4/sp5 pieces.
        let p = Partitioner { graph: &g, splits: &splits, theta: 2, big_threshold: 1000 };
        let (sets, stats) = p.partition_component(&triples, "c0");
        // Recursion produced passes labelled with the sub-component.
        assert!(stats.iter().any(|s| s.split == "sp4"), "{stats:?}");
        assert!(stats.iter().any(|s| s.split == "sp5"), "{stats:?}");
        // Sets now respect sp4/sp5 boundaries.
        for s in &sets {
            let in_sp4 = s.iter().any(|&n| {
                matches!(splits.split_of(AttrValueId(n).entity()), Some("sp3"))
                    && ["RESOLVED", "MTRCS", "MTRVALS", "KBROWS"]
                        .contains(&g.name_of(AttrValueId(n).entity()))
            });
            let in_sp5 = s.iter().any(|&n| {
                ["KBATTRS", "RPTROWS", "PUBSNAP", "IDXMAP"]
                    .contains(&g.name_of(AttrValueId(n).entity()))
            });
            assert!(!(in_sp4 && in_sp5), "set crosses sp4/sp5: {s:?}");
        }
    }

    #[test]
    fn no_intra_pass_set_dependencies() {
        // Criterion C1: sets from the same (split, component) pass are
        // disconnected within that split, so no triple joins them.
        let (g, splits) = text_curation_workflow();
        let triples = small_component(&g);
        let p = Partitioner { graph: &g, splits: &splits, theta: 1000, big_threshold: 1000 };
        let (sets, _) = p.partition_component(&triples, "c0");
        let set_of: FxHashMap<u64, usize> = sets
            .iter()
            .enumerate()
            .flat_map(|(i, s)| s.iter().map(move |&n| (n, i)))
            .collect();
        for t in &triples {
            let (a, b) = (set_of[&t.src.raw()], set_of[&t.dst.raw()]);
            if a != b {
                // Cross-set triples must cross splits too (same-pass sets
                // can't be joined by an intra-split edge).
                let sa = splits.split_of(AttrValueId(t.src.raw()).entity()).unwrap();
                let sb = splits.split_of(AttrValueId(t.dst.raw()).entity()).unwrap();
                assert_ne!(sa, sb, "intra-split edge joins two sets");
            }
        }
    }

    #[test]
    fn connectivity_invariant_holds() {
        let (g, splits) = text_curation_workflow();
        let triples = small_component(&g);
        let p = Partitioner { graph: &g, splits: &splits, theta: 1000, big_threshold: 1000 };
        let (sets, _) = p.partition_component(&triples, "c0");
        for s in &sets {
            let sp_name = splits.split_of(AttrValueId(s[0]).entity()).unwrap();
            let sp = splits.top_level().iter().find(|x| x.name() == sp_name).unwrap();
            assert!(
                is_weakly_connected_within(&triples, s, sp.entities()),
                "set not weakly connected in its split: {s:?}"
            );
        }
    }
}
