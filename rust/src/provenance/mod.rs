//! The paper's core: provenance data model, preprocessing (weakly
//! connected components, component partitioning, set dependencies) and the
//! three query engines (RQ, CCProv, CSProv).

pub mod model;
pub mod partition;
pub mod pipeline;
pub mod query;
pub mod setdeps;
pub mod store;
pub mod wcc;

pub use model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
pub use pipeline::{preprocess, Preprocessed};
