//! The paper's core: provenance data model, preprocessing (weakly
//! connected components, component partitioning, set dependencies),
//! incremental index maintenance, and the three query engines (RQ, CCProv,
//! CSProv).
//!
//! Offline path: [`preprocess`] runs WCC ([`wcc`]) → Algorithm 3
//! partitioning ([`partition`]) → tagging + set-dependency extraction
//! ([`setdeps`]), producing a [`Preprocessed`] index ([`store`] persists
//! it). Online path: [`incremental::IncrementalIndex`] keeps that index
//! live under [`incremental::TripleBatch`] deltas, and [`query`] answers
//! lineage requests over it. Scale-out path: [`shard`] carves the
//! component space into independent shards (components never reference
//! each other), served by `harness::ShardedSession`. Crash safety:
//! [`journal`] write-ahead-journals multi-step shard migrations and
//! two-phase-commits store publishes.

pub mod incremental;
pub mod journal;
pub mod model;
pub mod partition;
pub mod pipeline;
pub mod query;
pub mod setdeps;
pub mod shard;
pub mod store;
pub mod wcc;

pub use incremental::{AppliedDelta, DeltaStats, IncrementalIndex, TripleBatch};
pub use journal::{commit_files, recover_commit, CommitRecovery, MigrationJournal};
pub use model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
pub use pipeline::{preprocess, Preprocessed};
pub use shard::{merge_shards, ShardAssignment, ShardPlan};
