//! Incremental provenance ingestion: apply [`TripleBatch`] deltas to a
//! [`Preprocessed`] index **without recomputing it from scratch**.
//!
//! The paper precomputes WCC labels and τ-bounded weakly connected sets
//! offline and answers point queries over that static index. A production
//! service sees new provenance triples arrive *while* queries run (HyProv's
//! hybrid provenance argument), and a full [`preprocess`] re-run per batch
//! is a non-starter at scale. [`IncrementalIndex`] maintains every
//! preprocessing artifact under append-only deltas:
//!
//! * **WCC labels** — new triples union-merge component labels through a
//!   [`LabeledUnion`]: merging two components rewrites only the smaller
//!   side's labels (small-to-large, `O(n log n)` total relabel work over
//!   any append sequence). Labels are *representative* member ids, so they
//!   match a from-scratch run **up to relabelling** — [`canonical_labels`]
//!   maps both onto the minimum-member form for comparison.
//! * **Connected sets** — only components actually touched by the batch
//!   are marked *dirty*; each dirty component is re-run through
//!   [`Partitioner::partition_component`] when it has ≥ θ nodes (the same
//!   θ the index was built with, recorded in [`Preprocessed::theta`]) and
//!   kept as a single set otherwise. Untouched components are never
//!   revisited.
//! * **CCProv / CSProv schemas** — appended triples are tagged once;
//!   pre-existing rows are retagged only when their component or set
//!   actually changed, and the [`AppliedDelta`] records exactly those rows
//!   so the live engine datasets can absorb the delta through
//!   [`Dataset::append_partitioned`] / [`Dataset::patch_partitions`]
//!   instead of rebuilding (see `EngineSet::absorb`).
//! * **Set dependencies** — recomputed for dirty components only; deps of
//!   untouched components are retained as-is (a set dependency's two
//!   endpoints always lie in one component, so deps partition cleanly).
//!
//! The maintained index is *query-equivalent* to a from-scratch
//! [`preprocess`] of the concatenated trace: same component and set
//! partitions (up to label choice), same counts, and bit-identical answers
//! from all three engines — `rust/tests/incremental_props.rs` proves it
//! property-style, and `benches/bench_incremental.rs` proves the ≥10×
//! speedup over full re-preprocessing on a 1% append.
//!
//! [`Dataset::append_partitioned`]: crate::minispark::Dataset::append_partitioned
//! [`Dataset::patch_partitions`]: crate::minispark::Dataset::patch_partitions

use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::partition::Partitioner;
use crate::provenance::pipeline::{preprocess, Preprocessed, WccImpl};
use crate::provenance::wcc::LabeledUnion;
use crate::util::ids::{ComponentId, SetId};
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::SplitSet;
use anyhow::{bail, ensure, Result};
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::Arc;

/// A delta of newly arrived provenance triples (append-only — provenance
/// records derivations that happened; they are never retracted).
#[derive(Debug, Clone, Default)]
pub struct TripleBatch {
    pub triples: Vec<ProvTriple>,
}

impl TripleBatch {
    pub fn new(triples: Vec<ProvTriple>) -> Self {
        Self { triples }
    }

    pub fn len(&self) -> usize {
        self.triples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.triples.is_empty()
    }
}

impl From<Trace> for TripleBatch {
    fn from(t: Trace) -> Self {
        Self { triples: t.triples }
    }
}

/// What one [`IncrementalIndex::apply`] call did — the observable cost of
/// a delta, reported by the CLI `ingest` subcommand and asserted on by
/// `bench_incremental` (delta cost must track the *delta*, not the index).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Epoch after this batch (batches applied since the full preprocess).
    pub epoch: u64,
    pub new_triples: usize,
    pub new_nodes: usize,
    /// Component pairs union-merged by batch edges.
    pub components_merged: usize,
    /// Nodes whose WCC label was rewritten (always the smaller side).
    pub labels_rewritten: usize,
    /// Components touched by the batch (re-examined for set structure).
    pub dirty_components: usize,
    /// Triples living in dirty components (the retag scan bound).
    pub dirty_triples: usize,
    /// Dirty components ≥ θ that were re-run through Algorithm 3.
    pub repartitioned: usize,
    /// Pre-existing triples whose CC or CS tags actually changed.
    pub retagged_triples: usize,
    pub set_deps_removed: usize,
    pub set_deps_added: usize,
}

impl DeltaStats {
    /// One-line rendering for CLI / bench output.
    pub fn summary(&self) -> String {
        format!(
            "epoch={} new_triples={} new_nodes={} merged={} relabelled={} dirty_comps={} \
             dirty_triples={} repartitioned={} retagged={} deps-{}+{}",
            self.epoch,
            self.new_triples,
            self.new_nodes,
            self.components_merged,
            self.labels_rewritten,
            self.dirty_components,
            self.dirty_triples,
            self.repartitioned,
            self.retagged_triples,
            self.set_deps_removed,
            self.set_deps_added,
        )
    }
}

/// The structural delta one [`IncrementalIndex::apply`] produced, in the
/// exact shape the live engine datasets need to absorb it (see
/// `EngineSet::absorb`): which rows were appended, which pre-existing rows
/// were retagged (with their *old* tags, so the old copies can be located
/// and dropped), which nodes changed set, and the set-dependency diff.
#[derive(Debug, Clone, Default)]
pub struct AppliedDelta {
    pub stats: DeltaStats,
    /// Index of the first appended triple: `trace.triples[first_new_triple..]`
    /// (equivalently `pre.cc_triples` / `pre.cs_triples` — the three stay
    /// parallel) are this batch's new rows.
    pub first_new_triple: usize,
    /// Indices of pre-existing triples whose component id changed.
    pub retag_cc: Vec<u32>,
    /// Pre-existing triples whose set tags changed: `(index, old tags)`.
    pub retag_cs: Vec<(u32, CsTriple)>,
    /// Pre-existing nodes whose connected-set id changed: `(node, new csid)`.
    pub node_changes: Vec<(u64, u64)>,
    /// Nodes first seen in this batch: `(node, csid)`.
    pub new_nodes: Vec<(u64, u64)>,
    /// Set dependencies dropped (their component went dirty).
    pub removed_deps: Vec<SetDep>,
    /// Set dependencies recomputed for the dirty components.
    pub added_deps: Vec<SetDep>,
}

/// An incrementally maintained preprocessing index: owns the trace and its
/// [`Preprocessed`] artifacts plus the auxiliary structures (membership
/// lists, per-component triple index, per-component set counts) that make
/// delta application proportional to the *delta and its dirty components*
/// rather than the whole index.
///
/// Construction is `O(n)` (one pass over the existing index — paid once,
/// amortized over every subsequent batch); [`apply`](Self::apply) is
/// `O(batch + dirty)`: set-dependency classification reads only the dirty
/// components' dep buckets, and the global sorted dep list is updated by
/// a branch-light sorted-difference splice (linear in list length, but a
/// copy — no per-dep lookups).
pub struct IncrementalIndex {
    trace: Trace,
    pre: Preprocessed,
    labels: LabeledUnion,
    /// Component label → indices of its triples (parallel across
    /// `trace.triples` / `pre.cc_triples` / `pre.cs_triples`).
    tri_of: FxHashMap<u64, Vec<u32>>,
    /// Component label → number of connected sets it currently holds.
    set_count_of: FxHashMap<u64, usize>,
    /// Component label → its current set dependencies. Both endpoint sets
    /// of a dep lie in one component (the triple witnessing the dep
    /// connects them), so deps partition cleanly by component. Folded
    /// small-to-large through merges like `tri_of`; the phase-4 diff
    /// consults only the dirty components' buckets.
    deps_of: FxHashMap<u64, Vec<SetDep>>,
    graph: DependencyGraph,
    splits: SplitSet,
}

impl IncrementalIndex {
    /// Adopt an existing trace + preprocessed index. The workflow graph and
    /// splits must be the ones the index was preprocessed with (Algorithm 3
    /// re-partitions dirty components against them).
    ///
    /// Fails when `pre` does not cover `trace`, or when `pre` predates the
    /// incremental-epoch format (θ unrecorded — re-run `preprocess`).
    pub fn new(
        trace: Trace,
        pre: Preprocessed,
        graph: DependencyGraph,
        splits: SplitSet,
    ) -> Result<Self> {
        ensure!(
            pre.cc_triples.len() == trace.len() && pre.cs_triples.len() == trace.len(),
            "preprocessed index covers {} cc / {} cs triples but the trace has {}",
            pre.cc_triples.len(),
            pre.cs_triples.len(),
            trace.len(),
        );
        if pre.theta == 0 {
            // θ = 0 is also what a legacy (v1, pre-epoch-header) store file
            // loads as — the two are indistinguishable, so both are refused.
            bail!(
                "preprocessed index has θ = 0: either it predates the v2 store format \
                 (no recorded θ) or it was preprocessed with θ = 0; re-run `preprocess` \
                 with θ ≥ 1 to enable ingestion"
            );
        }
        // Dirty components are re-partitioned against `graph`/`splits`; an
        // index preprocessed under a different workflow would silently
        // mis-partition. A recorded fingerprint (v3 store header) makes the
        // mismatch detectable; 0 = unrecorded (legacy v1/v2 files) and is
        // accepted on trust, as before.
        let session_fp = crate::workflow::workflow_fingerprint(&graph, &splits);
        ensure!(
            pre.workflow_fingerprint == 0 || pre.workflow_fingerprint == session_fp,
            "preprocessed index was built under a different workflow (recorded \
             fingerprint {:#018x}, this graph/splits {:#018x}): ingesting would silently \
             mis-partition dirty components — construct the index with the workflow it \
             was preprocessed under, or re-run `preprocess`",
            pre.workflow_fingerprint,
            session_fp,
        );
        ensure!(trace.len() <= u32::MAX as usize, "trace too large for the triple index");
        let labels = LabeledUnion::from_labels(&pre.cc_of);
        let mut tri_of: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
        for (i, t) in trace.triples.iter().enumerate() {
            let (Some(&ls), Some(&ld)) =
                (pre.cc_of.get(&t.src.raw()), pre.cc_of.get(&t.dst.raw()))
            else {
                bail!(
                    "preprocessed index does not cover the trace: triple {i} \
                     ({} -> {}) has an unlabelled endpoint (index built from a \
                     different trace?)",
                    t.src,
                    t.dst,
                );
            };
            ensure!(
                ls == ld,
                "preprocessed index is inconsistent with the trace: triple {i} \
                 ({} -> {}) spans component labels {ls} and {ld}",
                t.src,
                t.dst,
            );
            tri_of.entry(ld).or_default().push(i as u32);
        }
        let mut sets_of: FxHashMap<u64, FxHashSet<u64>> = FxHashMap::default();
        for (&node, &sid) in &pre.cs_of {
            let Some(&l) = pre.cc_of.get(&node) else {
                bail!(
                    "preprocessed index is internally inconsistent: node {node} has a set id \
                     but no component label"
                );
            };
            sets_of.entry(l).or_default().insert(sid);
        }
        let set_count_of: FxHashMap<u64, usize> =
            sets_of.into_iter().map(|(cc, s)| (cc, s.len())).collect();
        let mut deps_of: FxHashMap<u64, Vec<SetDep>> = FxHashMap::default();
        for d in &pre.set_deps {
            // A set id is a member node, so its component label locates
            // the dep's (single) component.
            let Some(&l) = pre.cc_of.get(&d.src_csid.0) else {
                bail!(
                    "preprocessed index is internally inconsistent: set dependency \
                     {} -> {} has an unlabelled source set",
                    d.src_csid.0,
                    d.dst_csid.0,
                );
            };
            deps_of.entry(l).or_default().push(*d);
        }
        Ok(Self { trace, pre, labels, tri_of, set_count_of, deps_of, graph, splits })
    }

    /// Convenience: run the full [`preprocess`] pipeline on `trace` and wrap
    /// the result for ingestion.
    pub fn build(
        trace: Trace,
        graph: DependencyGraph,
        splits: SplitSet,
        theta: usize,
        big_threshold: usize,
    ) -> Result<Self> {
        let pre = preprocess(&trace, &graph, &splits, theta, big_threshold, WccImpl::Driver);
        Self::new(trace, pre, graph, splits)
    }

    /// The maintained trace.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// The maintained preprocessing artifacts.
    pub fn pre(&self) -> &Preprocessed {
        &self.pre
    }

    /// Batches applied since the last full preprocess.
    pub fn epoch(&self) -> u64 {
        self.pre.epoch
    }

    /// Clone the maintained state into fresh `Arc`s — the epoch-swap input
    /// for `ProvSession::ingest` (in-flight queries keep the previous
    /// epoch's `Arc`s alive; this one becomes the new current epoch).
    pub fn snapshot(&self) -> (Arc<Trace>, Arc<Preprocessed>) {
        (Arc::new(self.trace.clone()), Arc::new(self.pre.clone()))
    }

    /// Apply one batch of new triples, updating every preprocessing
    /// artifact in place. Returns the [`AppliedDelta`] describing exactly
    /// what changed (for engine-dataset absorption) plus its cost.
    pub fn apply(&mut self, batch: &TripleBatch) -> Result<AppliedDelta> {
        ensure!(
            self.trace.len() + batch.len() <= u32::MAX as usize,
            "trace would exceed the u32 triple index"
        );
        let first_new = self.trace.len();
        let mut delta = AppliedDelta {
            first_new_triple: first_new,
            ..Default::default()
        };
        let stats = &mut delta.stats;
        stats.new_triples = batch.len();

        // ---- Phase 1: WCC maintenance (union-merge labels) ----------------
        // One representative endpoint per batch triple marks its (final)
        // component dirty; merged-away labels are tracked so stale
        // `large_components` entries can be retired.
        let mut dirty_reps: FxHashSet<u64> = FxHashSet::default();
        let mut merged_away: FxHashSet<u64> = FxHashSet::default();
        for t in &batch.triples {
            self.trace.triples.push(*t);
            let (s, d) = (t.src.raw(), t.dst.raw());
            for n in [s, d] {
                if self.labels.insert(n) {
                    stats.new_nodes += 1;
                    self.pre.cc_of.insert(n, n);
                    self.pre.component_count += 1;
                }
            }
            let m = self.labels.union(s, d);
            if let Some(loser) = m.absorbed {
                stats.components_merged += 1;
                self.pre.component_count -= 1;
                merged_away.insert(loser);
                // The loser's label may itself have been a dirty rep or the
                // winner of an earlier merge this batch; membership in
                // `merged_away` retires it everywhere below.
                let members = self.labels.members(m.winner);
                stats.labels_rewritten += members.len() - m.relabelled_from;
                for &n in &members[m.relabelled_from..] {
                    self.pre.cc_of.insert(n, m.winner);
                }
                // Fold the absorbed component's triple index, dep bucket
                // and set count into the winner's.
                if let Some(moved) = self.tri_of.remove(&loser) {
                    self.tri_of.entry(m.winner).or_default().extend(moved);
                }
                if let Some(moved) = self.deps_of.remove(&loser) {
                    self.deps_of.entry(m.winner).or_default().extend(moved);
                }
                let loser_sets = self.set_count_of.remove(&loser).unwrap_or(0);
                *self.set_count_of.entry(m.winner).or_insert(0) += loser_sets;
            }
            dirty_reps.insert(s);
        }

        // ---- Phase 2: register + tag the appended triples ------------------
        // Tags are provisional here (set ids are assigned in the dirty pass,
        // which always covers these rows — their component is dirty by
        // construction).
        for (i, t) in batch.triples.iter().enumerate() {
            let idx = (first_new + i) as u32;
            let l = self.labels.label(t.dst.raw()).expect("appended node labelled");
            self.tri_of.entry(l).or_default().push(idx);
            self.pre.cc_triples.push(CcTriple { triple: *t, ccid: ComponentId(l) });
            self.pre.cs_triples.push(CsTriple {
                triple: *t,
                src_csid: SetId(0),
                dst_csid: SetId(0),
            });
        }

        // ---- Phase 3: recompute set structure of dirty components ----------
        let dirty_set: FxHashSet<u64> = dirty_reps
            .iter()
            .map(|&n| self.labels.label(n).expect("batch node labelled"))
            .collect();
        let mut dirty: Vec<u64> = dirty_set.iter().copied().collect();
        dirty.sort_unstable();
        stats.dirty_components = dirty.len();

        let mut added_deps: Vec<SetDep> = Vec::new();
        let mut removed_deps: Vec<SetDep> = Vec::new();
        for &l in &dirty {
            let tris = self.tri_of.get(&l).cloned().unwrap_or_default();
            stats.dirty_triples += tris.len();
            let nodes = self.labels.members(l);

            // New connected-set assignment for this component: Algorithm 3
            // when it reached θ, one set (labelled by the component) below.
            let new_cs: FxHashMap<u64, u64> = if nodes.len() >= self.pre.theta {
                stats.repartitioned += 1;
                let triples: Vec<ProvTriple> =
                    tris.iter().map(|&i| self.trace.triples[i as usize]).collect();
                let partitioner = Partitioner {
                    graph: &self.graph,
                    splits: &self.splits,
                    theta: self.pre.theta,
                    big_threshold: self.pre.big_threshold,
                };
                let label = format!("cc{l}@e{}", self.pre.epoch + 1);
                let (sets, _pass_stats) = partitioner.partition_component(&triples, &label);
                let mut out: FxHashMap<u64, u64> =
                    FxHashMap::with_capacity_and_hasher(nodes.len(), Default::default());
                for set in sets {
                    let sid = *set.iter().min().expect("non-empty set");
                    for n in set {
                        out.insert(n, sid);
                    }
                }
                // Pipeline parity: a node whose entity no split covers
                // falls back to the component's **minimum member id** as
                // its set id — exactly the value `preprocess` backfills
                // (its labels are min-ids; ours are representatives, so
                // the raw label would diverge).
                let fallback = *nodes.iter().min().expect("non-empty component");
                for &n in nodes {
                    out.entry(n).or_insert(fallback);
                }
                out
            } else {
                nodes.iter().map(|&n| (n, l)).collect()
            };

            // Set-count bookkeeping: a component's set count is its number
            // of **distinct set ids** — the same definition `preprocess`
            // uses for the global total and `Self::new` reconstructs, so
            // the three never drift (the global total tracks per-component
            // counts; merged-away counts were folded into `l` in phase 1).
            let new_set_count =
                new_cs.values().copied().collect::<FxHashSet<u64>>().len();
            let old_sets = self.set_count_of.insert(l, new_set_count).unwrap_or(0);
            self.pre.set_count = self.pre.set_count - old_sets + new_set_count;

            // Node → set updates, split into "changed" vs "first seen"
            // (nodes new this batch have no prior `cs_of` entry — each node
            // belongs to exactly one component, so this pass is their one
            // and only assignment).
            for (&node, &sid) in &new_cs {
                match self.pre.cs_of.insert(node, sid) {
                    None => delta.new_nodes.push((node, sid)),
                    Some(old_sid) if old_sid != sid => delta.node_changes.push((node, sid)),
                    Some(_) => {}
                }
            }

            // Retag this component's triples where the tags really changed.
            for &i in &tris {
                let iu = i as usize;
                let t = self.trace.triples[iu];
                let new_cc = CcTriple { triple: t, ccid: ComponentId(l) };
                let new_cs_row = CsTriple {
                    triple: t,
                    src_csid: SetId(new_cs[&t.src.raw()]),
                    dst_csid: SetId(new_cs[&t.dst.raw()]),
                };
                if iu >= first_new {
                    // Appended rows: finalize the provisional tags in place.
                    self.pre.cc_triples[iu] = new_cc;
                    self.pre.cs_triples[iu] = new_cs_row;
                    continue;
                }
                let mut touched = false;
                if self.pre.cc_triples[iu] != new_cc {
                    delta.retag_cc.push(i);
                    self.pre.cc_triples[iu] = new_cc;
                    touched = true;
                }
                if self.pre.cs_triples[iu] != new_cs_row {
                    delta.retag_cs.push((i, self.pre.cs_triples[iu]));
                    self.pre.cs_triples[iu] = new_cs_row;
                    touched = true;
                }
                if touched {
                    stats.retagged_triples += 1;
                }
            }

            // Recompute this component's set dependencies (distinct
            // cross-set pairs among its triples). The old bucket — which
            // phase 1 already folded merged-away losers into — is exactly
            // this component's share of the global list; it drains into
            // `removed_deps` and the recomputed deps replace it.
            if let Some(old) = self.deps_of.remove(&l) {
                removed_deps.extend(old);
            }
            let mut seen: FxHashSet<(u64, u64)> = FxHashSet::default();
            let mut comp_deps: Vec<SetDep> = Vec::new();
            for &i in &tris {
                let row = self.pre.cs_triples[i as usize];
                if row.src_csid != row.dst_csid
                    && seen.insert((row.src_csid.0, row.dst_csid.0))
                {
                    comp_deps.push(SetDep {
                        src_csid: row.src_csid,
                        dst_csid: row.dst_csid,
                    });
                }
            }
            if !comp_deps.is_empty() {
                added_deps.extend_from_slice(&comp_deps);
                self.deps_of.insert(l, comp_deps);
            }
        }

        // ---- Phase 4: set-dependency diff ----------------------------------
        // A dependency's two endpoint sets always lie in one component (the
        // triple witnessing it connects them), so deps of untouched
        // components are retained verbatim and the per-component buckets
        // (`deps_of`, folded through merges in phase 1) named the dirty
        // components' old deps exactly — classification cost `O(dirty
        // deps)`, no per-dep label lookup over the global list. Set-dep
        // pairs are globally unique (a set id is a member node, so a pair
        // cannot recur in another component), which turns the global update
        // into a sorted-difference splice: one branch-light linear pass
        // skips the (sorted) removed entries, then a two-run merge folds
        // the recomputed deps back in — still linear in `|deps|`, but a
        // copy, not the old label-lookup + dirty-set probe per dep.
        let mut removed = removed_deps;
        removed.sort_unstable();
        added_deps.sort_unstable();
        let old_deps = std::mem::take(&mut self.pre.set_deps);
        let mut kept: Vec<SetDep> =
            Vec::with_capacity(old_deps.len().saturating_sub(removed.len()));
        let mut r = 0;
        for d in old_deps {
            if r < removed.len() && removed[r] == d {
                r += 1;
            } else {
                kept.push(d);
            }
        }
        debug_assert_eq!(r, removed.len(), "every drained bucket dep was in the global list");
        // `kept` is a subsequence of the previously sorted list, so a
        // linear two-run merge restores the sorted invariant.
        let mut merged = Vec::with_capacity(kept.len() + added_deps.len());
        let (mut i, mut j) = (0, 0);
        while i < kept.len() && j < added_deps.len() {
            if kept[i] <= added_deps[j] {
                merged.push(kept[i]);
                i += 1;
            } else {
                merged.push(added_deps[j]);
                j += 1;
            }
        }
        merged.extend_from_slice(&kept[i..]);
        merged.extend_from_slice(&added_deps[j..]);
        self.pre.set_deps = merged;
        stats.set_deps_removed = removed.len();
        stats.set_deps_added = added_deps.len();
        delta.removed_deps = removed;
        delta.added_deps = added_deps;

        // ---- Phase 5: large-component inventory ----------------------------
        self.pre
            .large_components
            .retain(|(cc, _, _)| !dirty_set.contains(cc) && !merged_away.contains(cc));
        for &l in &dirty {
            let n = self.labels.members(l).len();
            if n >= self.pre.theta {
                let edges = self.tri_of.get(&l).map(|v| v.len()).unwrap_or(0);
                self.pre.large_components.push((l, n, edges));
            }
        }
        self.pre.large_components.sort_unstable_by(|a, b| b.1.cmp(&a.1));

        self.pre.epoch += 1;
        stats.epoch = self.pre.epoch;
        Ok(delta)
    }
}

/// Structural equivalence of two preprocessed indexes **up to label
/// choice**: same component and set partitions (after [`canonical_labels`]
/// normalization), same component/set counts, same canonical
/// set-dependency relation, same canonical large-component inventory.
///
/// This is the single definition of "the incremental index equals a
/// from-scratch [`preprocess`]" — shared by this module's unit tests,
/// `rust/tests/incremental_props.rs`, and `benches/bench_incremental.rs`.
/// Returns the first divergence as an error string (the shape the property
/// harness consumes).
pub fn check_equivalence(a: &Preprocessed, b: &Preprocessed) -> std::result::Result<(), String> {
    if canonical_labels(&a.cc_of) != canonical_labels(&b.cc_of) {
        return Err("cc_of partitions diverge".into());
    }
    if canonical_labels(&a.cs_of) != canonical_labels(&b.cs_of) {
        return Err("cs_of partitions diverge".into());
    }
    if a.component_count != b.component_count {
        return Err(format!(
            "component_count {} != {}",
            a.component_count, b.component_count
        ));
    }
    if a.set_count != b.set_count {
        return Err(format!("set_count {} != {}", a.set_count, b.set_count));
    }
    let canon_deps = |pre: &Preprocessed| -> Vec<(u64, u64)> {
        let c = canonical_of(&pre.cs_of);
        let mut v: Vec<(u64, u64)> =
            pre.set_deps.iter().map(|d| (c[&d.src_csid.0], c[&d.dst_csid.0])).collect();
        v.sort_unstable();
        v
    };
    if canon_deps(a) != canon_deps(b) {
        return Err("set-dependency relations diverge".into());
    }
    let canon_large = |pre: &Preprocessed| -> Vec<(u64, usize, usize)> {
        let c = canonical_of(&pre.cc_of);
        let mut v: Vec<(u64, usize, usize)> =
            pre.large_components.iter().map(|&(cc, n, e)| (c[&cc], n, e)).collect();
        v.sort_unstable();
        v
    };
    if canon_large(a) != canon_large(b) {
        return Err("large-component inventories diverge".into());
    }
    Ok(())
}

/// Canonicalize a `node → label` map by replacing each label with the
/// minimum member id of its group. Two labellings describing the same
/// partition (WCC labels from [`preprocess`] vs an [`IncrementalIndex`],
/// whose merge keeps the *larger* side's label) canonicalize identically.
pub fn canonical_labels(labels: &FxHashMap<u64, u64>) -> FxHashMap<u64, u64> {
    let canon = canonical_of(labels);
    labels.iter().map(|(&n, &l)| (n, canon[&l])).collect()
}

/// The `label → canonical (minimum member) label` map underlying
/// [`canonical_labels`] — useful for canonicalizing *references* to labels
/// (set-dependency endpoints, large-component ids).
pub fn canonical_of(labels: &FxHashMap<u64, u64>) -> FxHashMap<u64, u64> {
    let mut min_of: FxHashMap<u64, u64> = FxHashMap::default();
    for (&n, &l) in labels {
        min_of.entry(l).and_modify(|m| *m = (*m).min(n)).or_insert(n);
    }
    min_of
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::curation::text_curation_workflow;
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn scratch(trace: &Trace, theta: usize) -> Preprocessed {
        let (g, splits) = text_curation_workflow();
        preprocess(trace, &g, &splits, theta, 100, WccImpl::Driver)
    }

    fn index(trace: Trace, theta: usize) -> IncrementalIndex {
        let (g, splits) = text_curation_workflow();
        IncrementalIndex::build(trace, g, splits, theta, 100).unwrap()
    }

    fn assert_equivalent(idx: &IncrementalIndex, want: &Preprocessed) {
        let got = idx.pre();
        // The shared structural check (partitions, counts, deps, large
        // components)…
        check_equivalence(got, want).unwrap();
        // …plus the row-level tag check only the maintained arrays can
        // diverge on: every triple's tags agree after canonicalization.
        let (gc, wc) = (canonical_of(&got.cs_of), canonical_of(&want.cs_of));
        let (gl, wl) = (canonical_of(&got.cc_of), canonical_of(&want.cc_of));
        for (g_row, w_row) in got.cc_triples.iter().zip(&want.cc_triples) {
            assert_eq!(g_row.triple, w_row.triple);
            assert_eq!(gl[&g_row.ccid.0], wl[&w_row.ccid.0]);
        }
        for (g_row, w_row) in got.cs_triples.iter().zip(&want.cs_triples) {
            assert_eq!(g_row.triple, w_row.triple);
            assert_eq!(gc[&g_row.src_csid.0], wc[&w_row.src_csid.0]);
            assert_eq!(gc[&g_row.dst_csid.0], wc[&w_row.dst_csid.0]);
        }
    }

    #[test]
    fn rejects_mismatched_or_pre_epoch_input() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        // θ unrecorded (old index format) → refused.
        pre.theta = 0;
        let (g2, s2) = text_curation_workflow();
        assert!(IncrementalIndex::new(trace.clone(), pre, g2, s2).is_err());
        // Truncated artifacts → refused.
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.cc_triples.pop();
        let (g3, s3) = text_curation_workflow();
        assert!(IncrementalIndex::new(trace.clone(), pre, g3, s3).is_err());
        // A recorded workflow fingerprint that does not match the session's
        // graph/splits → refused loudly (the mismatch would silently
        // mis-partition dirty components).
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        assert_ne!(pre.workflow_fingerprint, 0);
        pre.workflow_fingerprint ^= 1;
        let (g5, s5) = text_curation_workflow();
        let err = IncrementalIndex::new(trace.clone(), pre, g5, s5).unwrap_err();
        assert!(format!("{err:#}").contains("different workflow"), "{err:#}");
        // …while an unrecorded (legacy) fingerprint is accepted on trust.
        let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        pre.workflow_fingerprint = 0;
        let (g6, s6) = text_curation_workflow();
        assert!(IncrementalIndex::new(trace.clone(), pre, g6, s6).is_ok());
        // An index that does not label the trace's nodes (e.g. built from a
        // different trace) → a named error, not a map-index panic — on
        // either endpoint.
        for endpoint in [trace.triples[0].dst.raw(), trace.triples[0].src.raw()] {
            let mut pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
            pre.cc_of.remove(&endpoint);
            let (g4, s4) = text_curation_workflow();
            let err = IncrementalIndex::new(trace.clone(), pre, g4, s4).unwrap_err();
            assert!(format!("{err:#}").contains("does not cover"), "{err:#}");
        }
    }

    #[test]
    fn empty_batch_bumps_epoch_only() {
        let (trace, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let before = scratch(&trace, 200);
        let mut idx = index(trace, 200);
        let delta = idx.apply(&TripleBatch::default()).unwrap();
        assert_eq!(delta.stats.epoch, 1);
        assert_eq!(delta.stats.new_triples, 0);
        assert_eq!(delta.stats.dirty_components, 0);
        assert!(delta.retag_cc.is_empty() && delta.retag_cs.is_empty());
        assert_eq!(idx.epoch(), 1);
        assert_equivalent(&idx, &before);
    }

    #[test]
    fn single_batch_matches_scratch() {
        let (full, _, _) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let cut = full.len() * 9 / 10;
        let base = Trace::new(full.triples[..cut].to_vec());
        let batch = TripleBatch::new(full.triples[cut..].to_vec());
        let mut idx = index(base, 150);
        let delta = idx.apply(&batch).unwrap();
        assert_eq!(delta.stats.new_triples, full.len() - cut);
        assert_eq!(idx.trace().len(), full.len());
        assert_equivalent(&idx, &scratch(&full, 150));
    }

    #[test]
    fn dep_buckets_always_flatten_to_the_global_list() {
        // The phase-4 diff trusts `deps_of` to partition `pre.set_deps`
        // exactly; check the invariant through appends, a cross-component
        // merge, and a θ-crossing repartition.
        let (full, _, _) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let cut = full.len() * 8 / 10;
        let base = Trace::new(full.triples[..cut].to_vec());
        let mut idx = index(base, 150);
        let check = |idx: &IncrementalIndex| {
            let mut flat: Vec<SetDep> =
                idx.deps_of.values().flat_map(|v| v.iter().copied()).collect();
            flat.sort_unstable();
            let mut global = idx.pre.set_deps.clone();
            global.sort_unstable();
            assert_eq!(flat, global, "buckets and global dep list diverged");
            // Every bucket key is a live component label.
            for (&l, deps) in &idx.deps_of {
                assert!(!deps.is_empty(), "empty bucket for {l} left behind");
                assert_eq!(idx.labels.label(l), Some(l), "bucket key {l} is stale");
            }
        };
        check(&idx);
        for chunk in full.triples[cut..].chunks(full.len() / 20 + 1) {
            idx.apply(&TripleBatch::new(chunk.to_vec())).unwrap();
            check(&idx);
        }
        // Bridge the two largest components (a merge that folds buckets).
        let pre = idx.pre();
        assert!(pre.large_components.len() >= 2, "need two large components");
        let (a, _, _) = pre.large_components[0];
        let (b, _, _) = pre.large_components[1];
        let a_node = *idx.labels.members(a).iter().min().unwrap();
        let b_node = *idx.labels.members(b).iter().min().unwrap();
        let bridge = ProvTriple::new(
            crate::util::ids::AttrValueId(a_node),
            crate::util::ids::AttrValueId(b_node),
            crate::util::ids::OpId(0),
        );
        idx.apply(&TripleBatch::new(vec![bridge])).unwrap();
        check(&idx);
        assert_equivalent(&idx, &scratch(idx.trace(), 150));
    }

    #[test]
    fn merge_rewrites_only_smaller_side() {
        // Two disjoint halves of the trace, then one bridging triple: the
        // merge must relabel at most the smaller component.
        let (full, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let base = Trace::new(full.triples.clone());
        let mut idx = index(base, 200);
        // Bridge the two largest components.
        let pre = idx.pre();
        assert!(pre.large_components.len() >= 2, "need two large components");
        let (a, a_nodes, _) = pre.large_components[0];
        let (b, b_nodes, _) = pre.large_components[1];
        let a_node = *idx.labels.members(a).iter().min().unwrap();
        let b_node = *idx.labels.members(b).iter().min().unwrap();
        let bridge = ProvTriple::new(
            crate::util::ids::AttrValueId(a_node),
            crate::util::ids::AttrValueId(b_node),
            crate::util::ids::OpId(0),
        );
        let delta = idx.apply(&TripleBatch::new(vec![bridge])).unwrap();
        assert_eq!(delta.stats.components_merged, 1);
        assert_eq!(delta.stats.labels_rewritten, a_nodes.min(b_nodes));
        // Equivalent to preprocessing the bridged trace from scratch.
        let mut bridged = full.clone();
        bridged.triples.push(bridge);
        assert_equivalent(&idx, &scratch(&bridged, 200));
    }

    #[test]
    fn growth_past_theta_triggers_repartition() {
        // Start with a θ so high nothing is partitioned, then append a copy
        // of the trace's largest component... simpler: use a θ just above
        // the largest component and let a merge of the top two push past it.
        let (full, _, _) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let probe = index(full.clone(), 50);
        let (a, a_nodes, _) = probe.pre().large_components[0];
        let (b, b_nodes, _) = probe.pre().large_components[1];
        let theta = a_nodes + 1; // neither component is large alone…
        let mut idx = index(full.clone(), theta);
        assert!(idx.pre().large_components.is_empty());
        let a_node = *probe.labels.members(a).iter().min().unwrap();
        let b_node = *probe.labels.members(b).iter().min().unwrap();
        let bridge = ProvTriple::new(
            crate::util::ids::AttrValueId(a_node),
            crate::util::ids::AttrValueId(b_node),
            crate::util::ids::OpId(0),
        );
        let delta = idx.apply(&TripleBatch::new(vec![bridge])).unwrap();
        // …but the merged one is, so it got re-run through Algorithm 3.
        assert_eq!(delta.stats.repartitioned, 1);
        assert_eq!(idx.pre().large_components.len(), 1);
        assert_eq!(idx.pre().large_components[0].1, a_nodes + b_nodes);
        let mut bridged = full;
        bridged.triples.push(bridge);
        assert_equivalent(&idx, &scratch(&bridged, theta));
    }

    #[test]
    fn canonical_labels_collapse_representatives() {
        let mut a: FxHashMap<u64, u64> = FxHashMap::default();
        let mut b: FxHashMap<u64, u64> = FxHashMap::default();
        // Same partition {1,5,9} + {2}, different representatives.
        for n in [1, 5, 9] {
            a.insert(n, 9);
            b.insert(n, 1);
        }
        a.insert(2, 2);
        b.insert(2, 2);
        assert_eq!(canonical_labels(&a), canonical_labels(&b));
        assert_eq!(canonical_of(&a)[&9], 1);
    }
}
