//! Set-dependency extraction (paper §3, "Computing Set Dependencies").
//!
//! After Algorithm 3 assigns every node a connected-set id, the distinct
//! `(src_csid, dst_csid)` pairs of triples whose endpoints fall in
//! different sets form the set-dependency relation: set `dst_csid` (child)
//! is derived from set `src_csid` (parent).

use crate::minispark::{Dataset, MiniSpark};
use crate::provenance::model::{CsTriple, SetDep};
use crate::util::ids::SetId;
use rustc_hash::FxHashSet;

/// Driver-side extraction (used by the preprocessing pipeline).
pub fn set_deps_driver(cs_triples: &[CsTriple]) -> Vec<SetDep> {
    let mut seen: FxHashSet<(u64, u64)> = FxHashSet::default();
    let mut out = Vec::new();
    for t in cs_triples {
        if t.src_csid != t.dst_csid && seen.insert((t.src_csid.0, t.dst_csid.0)) {
            out.push(SetDep { src_csid: t.src_csid, dst_csid: t.dst_csid });
        }
    }
    out.sort_unstable();
    out
}

/// Distributed extraction on minispark: shuffle cross-set triples by the
/// pair key and deduplicate per partition (how a Spark job would do it on
/// a trace too large for the driver).
pub fn set_deps_minispark(
    sc: &MiniSpark,
    cs_triples: &[CsTriple],
    num_partitions: usize,
) -> Vec<SetDep> {
    let rows: Vec<(u64, u64)> = cs_triples
        .iter()
        .filter(|t| t.src_csid != t.dst_csid)
        .map(|t| (t.src_csid.0, t.dst_csid.0))
        .collect();
    let ds = Dataset::from_vec(sc, rows, num_partitions);
    // Key by a mix of both ids so identical pairs co-locate.
    let deduped = ds.reduce_by_key(
        num_partitions,
        |&(s, d)| (crate::util::rng::mix64(s) ^ d.rotate_left(17), vec![(s, d)]),
        |mut a, b| {
            for p in b {
                if !a.contains(&p) {
                    a.push(p);
                }
            }
            a
        },
    );
    let mut out: Vec<SetDep> = deduped
        .collect()
        .into_iter()
        .flat_map(|(_, pairs)| pairs)
        .map(|(s, d)| SetDep { src_csid: SetId(s), dst_csid: SetId(d) })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::model::ProvTriple;
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn cs(src_set: u64, dst_set: u64, n: u64) -> CsTriple {
        CsTriple {
            triple: ProvTriple::new(
                AttrValueId::new(EntityId(0), n),
                AttrValueId::new(EntityId(1), n),
                OpId(0),
            ),
            src_csid: SetId(src_set),
            dst_csid: SetId(dst_set),
        }
    }

    #[test]
    fn dedups_and_skips_intra_set() {
        let triples =
            vec![cs(1, 2, 0), cs(1, 2, 1), cs(2, 2, 2), cs(2, 3, 3), cs(1, 3, 4)];
        let deps = set_deps_driver(&triples);
        assert_eq!(
            deps,
            vec![
                SetDep { src_csid: SetId(1), dst_csid: SetId(2) },
                SetDep { src_csid: SetId(1), dst_csid: SetId(3) },
                SetDep { src_csid: SetId(2), dst_csid: SetId(3) },
            ]
        );
    }

    #[test]
    fn minispark_matches_driver() {
        let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() });
        let triples: Vec<CsTriple> =
            (0..500).map(|i| cs(i % 7, i % 5, i)).collect();
        let a = set_deps_driver(&triples);
        let b = set_deps_minispark(&sc, &triples, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        assert!(set_deps_driver(&[]).is_empty());
    }
}
