//! Component-space sharding: the plan that carves one preprocessed
//! provenance index into N independent shards.
//!
//! The paper's observation is that a queried attribute-value's lineage is
//! confined to its weakly connected component — components never reference
//! each other. That makes the *component space* embarrassingly shardable:
//! assign every component (and thus every item, every tagged triple, every
//! set and set-dependency) to exactly one shard and no query ever needs a
//! cross-shard edge. A [`ShardPlan`] fixes that assignment by hashing each
//! component's **canonical label** (its minimum member id — stable across
//! the min-id labels a fresh [`preprocess`] produces and the
//! representative labels an
//! [`IncrementalIndex`](crate::provenance::incremental::IncrementalIndex)
//! maintains), so the same data always shards the same way regardless of
//! how its labelling was produced.
//!
//! [`Trace::split_by_plan`] and [`Preprocessed::split_by_plan`] partition
//! the artifacts under a materialized [`ShardAssignment`]; both iterate the
//! parallel triple arrays in the same order, so each shard's trace and
//! index stay row-parallel (the invariant `EngineSet::build` and
//! `IncrementalIndex::new` check). [`merge_shards`] is the inverse —
//! gather shard states back into one combined index (what the CLI persists
//! after a sharded ingest).
//!
//! The scatter-gather front that *serves* a sharded index lives in
//! [`crate::harness::ShardedSession`]; this module is only the data-layout
//! layer.
//!
//! [`preprocess`]: crate::provenance::pipeline::preprocess
//! [`Trace::split_by_plan`]: crate::provenance::model::Trace::split_by_plan
//! [`Preprocessed::split_by_plan`]: crate::provenance::pipeline::Preprocessed::split_by_plan

use crate::provenance::incremental::canonical_of;
use crate::provenance::model::Trace;
use crate::provenance::pipeline::Preprocessed;
use crate::util::rng::mix64;
use anyhow::{ensure, Result};
use rustc_hash::FxHashMap;
use std::sync::Arc;

/// A component-space sharding policy: `shards` buckets, components hashed
/// by canonical label.
///
/// ```
/// use provspark::provenance::shard::ShardPlan;
///
/// let plan = ShardPlan::new(4);
/// // Deterministic: the same component always maps to the same shard.
/// assert_eq!(plan.shard_of_label(42), plan.shard_of_label(42));
/// assert!(plan.shard_of_label(42) < 4);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    shards: usize,
}

impl ShardPlan {
    /// A plan over `shards ≥ 1` buckets.
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1, "a shard plan needs at least one shard");
        Self { shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning the component whose **canonical** (minimum member id)
    /// label is `canonical_label`.
    pub fn shard_of_label(&self, canonical_label: u64) -> usize {
        (mix64(canonical_label) % self.shards as u64) as usize
    }

    /// Deterministic shard for an item with no known component: unknown
    /// items answer identically (an empty lineage) on every shard, so any
    /// deterministic choice preserves equivalence; hashing the item spreads
    /// the misses. A brand-new component formed entirely by an ingested
    /// batch is also placed with this (keyed by its minimum node id — the
    /// canonical label it will have).
    pub fn shard_of_item(&self, item: u64) -> usize {
        self.shard_of_label(item)
    }

    /// Materialize the `component label → shard` assignment for a concrete
    /// labelling (any representative scheme — labels are canonicalized to
    /// minimum member ids before hashing).
    pub fn assignment(&self, cc_of: &FxHashMap<u64, u64>) -> ShardAssignment {
        let canon = canonical_of(cc_of);
        let of_label: FxHashMap<u64, usize> =
            canon.iter().map(|(&l, &c)| (l, self.shard_of_label(c))).collect();
        ShardAssignment { shards: self.shards, of_label }
    }
}

/// A concrete `component label → shard` map, as consumed by
/// [`Trace::split_by_plan`] / [`Preprocessed::split_by_plan`].
///
/// Usually built by [`ShardPlan::assignment`]; the sharded ingest path also
/// builds ad-hoc assignments (keep vs migrate buckets) when a cross-shard
/// component merge moves data between shards.
#[derive(Debug, Clone)]
pub struct ShardAssignment {
    shards: usize,
    of_label: FxHashMap<u64, usize>,
}

impl ShardAssignment {
    /// An explicit assignment. Every shard index in `of_label` must be
    /// `< shards`.
    pub fn new(shards: usize, of_label: FxHashMap<u64, usize>) -> Self {
        assert!(shards >= 1);
        debug_assert!(of_label.values().all(|&s| s < shards));
        Self { shards, of_label }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard of the component labelled `label` (as labelled in the data
    /// being split — not canonicalized), if covered.
    pub fn shard_of_label(&self, label: u64) -> Option<usize> {
        self.of_label.get(&label).copied()
    }
}

/// Gather shard states back into one combined `(Trace, Preprocessed)` —
/// the inverse of `split_by_plan`. Shards must agree on θ, the big-set
/// bound and the workflow fingerprint; the merged epoch is the maximum
/// shard epoch (shards ingest independently), and the merged header is
/// unsharded (`shard_index = shard_count = 0`).
pub fn merge_shards(parts: &[(Arc<Trace>, Arc<Preprocessed>)]) -> Result<(Trace, Preprocessed)> {
    ensure!(!parts.is_empty(), "cannot merge zero shards");
    let first = &parts[0].1;
    let mut out = Preprocessed {
        theta: first.theta,
        big_threshold: first.big_threshold,
        workflow_fingerprint: first.workflow_fingerprint,
        ..Default::default()
    };
    let mut trace = Trace::default();
    for (i, (t, p)) in parts.iter().enumerate() {
        ensure!(
            p.theta == out.theta
                && p.big_threshold == out.big_threshold
                && p.workflow_fingerprint == out.workflow_fingerprint,
            "shard {i} disagrees on θ / big-set bound / workflow fingerprint"
        );
        ensure!(
            p.cc_triples.len() == t.len() && p.cs_triples.len() == t.len(),
            "shard {i} index covers {} cc / {} cs rows but its trace has {}",
            p.cc_triples.len(),
            p.cs_triples.len(),
            t.len(),
        );
        trace.triples.extend_from_slice(&t.triples);
        out.cc_triples.extend_from_slice(&p.cc_triples);
        out.cs_triples.extend_from_slice(&p.cs_triples);
        out.set_deps.extend_from_slice(&p.set_deps);
        out.large_components.extend_from_slice(&p.large_components);
        for (&n, &l) in &p.cc_of {
            ensure!(
                out.cc_of.insert(n, l).is_none(),
                "node {n} appears on more than one shard"
            );
        }
        for (&n, &s) in &p.cs_of {
            out.cs_of.insert(n, s);
        }
        out.component_count += p.component_count;
        out.set_count += p.set_count;
        out.epoch = out.epoch.max(p.epoch);
    }
    out.set_deps.sort_unstable();
    out.large_components.sort_unstable_by(|a, b| b.1.cmp(&a.1));
    Ok((trace, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::incremental::check_equivalence;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};
    use rustc_hash::FxHashSet;

    #[test]
    fn plan_is_deterministic_and_in_range() {
        let plan = ShardPlan::new(5);
        for l in [0u64, 1, 7, 1 << 40, u64::MAX] {
            let s = plan.shard_of_label(l);
            assert!(s < 5);
            assert_eq!(s, plan.shard_of_label(l));
            assert_eq!(s, ShardPlan::new(5).shard_of_label(l));
        }
        // One shard: everything maps to it.
        let one = ShardPlan::new(1);
        assert_eq!(one.shard_of_label(123), 0);
    }

    #[test]
    fn assignment_ignores_representative_choice() {
        // Two labellings of the same partition — {1,5,9} under label 9 vs
        // label 1 — must shard identically (hash of the canonical label).
        let plan = ShardPlan::new(8);
        let mut a: FxHashMap<u64, u64> = FxHashMap::default();
        let mut b: FxHashMap<u64, u64> = FxHashMap::default();
        for n in [1u64, 5, 9] {
            a.insert(n, 9);
            b.insert(n, 1);
        }
        let (aa, ab) = (plan.assignment(&a), plan.assignment(&b));
        assert_eq!(aa.shard_of_label(9), ab.shard_of_label(1));
    }

    #[test]
    fn split_then_merge_roundtrips() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let plan = ShardPlan::new(3);
        let asg = plan.assignment(&pre.cc_of);
        let traces = trace.split_by_plan(&pre.cc_of, &asg).unwrap();
        let pres = pre.split_by_plan(&asg).unwrap();
        assert_eq!(traces.len(), 3);
        assert_eq!(pres.len(), 3);

        // Per-shard invariants: parallel rows, whole components, headers.
        let mut seen_nodes: FxHashSet<u64> = FxHashSet::default();
        for (i, (t, p)) in traces.iter().zip(&pres).enumerate() {
            assert_eq!(p.cc_triples.len(), t.len(), "shard {i} rows");
            assert_eq!(p.cs_triples.len(), t.len(), "shard {i} rows");
            assert_eq!(p.shard_index, i as u64);
            assert_eq!(p.shard_count, 3);
            assert_eq!(p.theta, pre.theta);
            assert_eq!(p.workflow_fingerprint, pre.workflow_fingerprint);
            for (j, tr) in t.triples.iter().enumerate() {
                assert_eq!(p.cc_triples[j].triple, *tr, "shard {i} row {j} misaligned");
                assert_eq!(p.cs_triples[j].triple, *tr, "shard {i} row {j} misaligned");
                assert!(p.cc_of.contains_key(&tr.src.raw()), "src off-shard");
                assert!(p.cc_of.contains_key(&tr.dst.raw()), "dst off-shard");
            }
            for &n in p.cc_of.keys() {
                assert!(seen_nodes.insert(n), "node {n} on two shards");
            }
        }
        assert!(traces.iter().filter(|t| !t.is_empty()).count() >= 2, "degenerate split");
        assert_eq!(seen_nodes.len(), pre.cc_of.len());

        // Merging back reproduces the original index structurally.
        let parts: Vec<(Arc<Trace>, Arc<Preprocessed>)> = traces
            .into_iter()
            .zip(pres)
            .map(|(t, p)| (Arc::new(t), Arc::new(p)))
            .collect();
        let (mt, mp) = merge_shards(&parts).unwrap();
        assert_eq!(mt.len(), trace.len());
        check_equivalence(&mp, &pre).unwrap();
        let mut a = mt.triples.clone();
        let mut b = trace.triples.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "triple multiset changed");
    }

    #[test]
    fn merge_rejects_mismatched_headers() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 4000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let asg = ShardPlan::new(2).assignment(&pre.cc_of);
        let traces = trace.split_by_plan(&pre.cc_of, &asg).unwrap();
        let mut pres = pre.split_by_plan(&asg).unwrap();
        pres[1].theta += 1;
        let parts: Vec<(Arc<Trace>, Arc<Preprocessed>)> = traces
            .into_iter()
            .zip(pres)
            .map(|(t, p)| (Arc::new(t), Arc::new(p)))
            .collect();
        assert!(merge_shards(&parts).is_err());
    }
}
