//! Provenance data model (paper §1).
//!
//! Provenance is a set of triples `⟨src, dst, op⟩`: attribute-value `dst`
//! was derived from attribute-value `src` by transformation `op`.
//! Preprocessing annotates triples either with their weakly connected
//! component id ([`CcTriple`], CCProv) or with the connected-set ids of
//! both endpoints ([`CsTriple`], CSProv — the paper drops `ccid` and adds
//! `src_csid`/`dst_csid`, Table 7).

use crate::provenance::shard::ShardAssignment;
use crate::util::ids::{AttrValueId, ComponentId, OpId, SetId};
use anyhow::{bail, Result};
use rustc_hash::{FxHashMap, FxHashSet};

/// `⟨src, dst, op⟩` — `dst` derived from `src` via transformation `op`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProvTriple {
    pub src: AttrValueId,
    pub dst: AttrValueId,
    pub op: OpId,
}

impl ProvTriple {
    pub fn new(src: AttrValueId, dst: AttrValueId, op: OpId) -> Self {
        Self { src, dst, op }
    }
}

/// A triple annotated with its component id (Table 4, CCProv schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CcTriple {
    pub triple: ProvTriple,
    pub ccid: ComponentId,
}

/// A triple annotated with the connected-set ids of both endpoints
/// (Table 7, CSProv schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CsTriple {
    pub triple: ProvTriple,
    pub src_csid: SetId,
    pub dst_csid: SetId,
}

/// A set dependency (Table 8): set `dst_csid` (child) is derived from set
/// `src_csid` (parent) — i.e. some triple has `src` in the parent set and
/// `dst` in the child set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SetDep {
    /// Parent set (contributes to the derivation).
    pub src_csid: SetId,
    /// Child set (is derived).
    pub dst_csid: SetId,
}

/// An in-memory provenance trace: the raw triples.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub triples: Vec<ProvTriple>,
}

impl Trace {
    pub fn new(triples: Vec<ProvTriple>) -> Self {
        Self { triples }
    }

    pub fn len(&self) -> usize {
        self.triples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.triples.is_empty()
    }

    /// Number of distinct attribute-values (graph nodes).
    pub fn node_count(&self) -> usize {
        let mut nodes: FxHashSet<AttrValueId> =
            FxHashSet::with_capacity_and_hasher(self.triples.len(), Default::default());
        for t in &self.triples {
            nodes.insert(t.src);
            nodes.insert(t.dst);
        }
        nodes.len()
    }

    /// All distinct nodes.
    pub fn nodes(&self) -> Vec<AttrValueId> {
        let mut nodes: FxHashSet<AttrValueId> =
            FxHashSet::with_capacity_and_hasher(self.triples.len(), Default::default());
        for t in &self.triples {
            nodes.insert(t.src);
            nodes.insert(t.dst);
        }
        nodes.into_iter().collect()
    }

    /// Partition the trace into per-shard traces under a component-space
    /// [`ShardAssignment`]: each triple follows its component (`cc_of` of
    /// its `dst` — both endpoints share a component by construction).
    ///
    /// Iterates in trace order, so the shard traces stay row-parallel with
    /// the shard indexes produced by
    /// [`Preprocessed::split_by_plan`](crate::provenance::pipeline::Preprocessed::split_by_plan)
    /// from the same assignment. Errors when the labelling or the
    /// assignment does not cover the trace.
    pub fn split_by_plan(
        &self,
        cc_of: &FxHashMap<u64, u64>,
        asg: &ShardAssignment,
    ) -> Result<Vec<Trace>> {
        let mut out: Vec<Trace> = (0..asg.shards()).map(|_| Trace::default()).collect();
        for (i, t) in self.triples.iter().enumerate() {
            let Some(&label) = cc_of.get(&t.dst.raw()) else {
                bail!(
                    "labelling does not cover the trace: triple {i} has unlabelled dst {}",
                    t.dst
                );
            };
            let Some(s) = asg.shard_of_label(label) else {
                bail!("shard assignment does not cover component {label} (triple {i})");
            };
            out[s].triples.push(*t);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::EntityId;

    fn av(e: u16, s: u64) -> AttrValueId {
        AttrValueId::new(EntityId(e), s)
    }

    #[test]
    fn node_count_dedups() {
        let t = Trace::new(vec![
            ProvTriple::new(av(0, 1), av(1, 1), OpId(0)),
            ProvTriple::new(av(0, 1), av(1, 2), OpId(0)),
            ProvTriple::new(av(1, 1), av(2, 1), OpId(1)),
        ]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.node_count(), 4);
        assert_eq!(t.nodes().len(), 4);
    }

    #[test]
    fn empty_trace() {
        let t = Trace::default();
        assert!(t.is_empty());
        assert_eq!(t.node_count(), 0);
    }
}
