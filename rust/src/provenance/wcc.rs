//! Weakly connected components of the provenance graph (paper §2.2).
//!
//! Three interchangeable implementations; all label every node with the
//! **minimum raw attribute-value id** in its component (the canonical
//! [`ComponentId`](crate::util::ids::ComponentId)):
//!
//! * [`wcc_driver`] — union-find on the driver. Fastest on one box; used
//!   as the correctness oracle and the default preprocessing path.
//! * [`wcc_minispark`] — distributed min-label propagation on the
//!   `minispark` engine (the paper computes WCC with a Spark
//!   implementation; this is the faithful reproduction of that phase
//!   and what `bench_preprocess` times).
//! * the XLA fixpoint in [`crate::runtime`] — the same label propagation
//!   compiled to an HLO `while`-loop from JAX/Pallas, executed via PJRT.
//!
//! Equivalence of all three is a property test (`rust/tests/wcc_props.rs`).
//!
//! ## Frontier (delta) propagation
//!
//! [`wcc_minispark`] is *frontier-based*: each round joins the adjacency
//! only against the set of nodes whose label **decreased** last round (the
//! frontier), instead of re-broadcasting every node's label every round.
//! Labels are monotone non-increasing, so a node that did not change has
//! nothing new to tell its neighbours — the classic delta-iteration
//! argument (GraphX/Pregel's `activeSetOpt`, Flink's delta iterations).
//! Per-round *shuffle volume* is `O(edges incident to the frontier)`
//! rather than `O(E + V)` (the narrow label merge still scans the label
//! state in place), and on skewed provenance traces the frontier
//! collapses after the first few rounds.
//!
//! The round structure leans on minispark's shuffle elision
//! ([`KeyTag`](crate::minispark::KeyTag)): the adjacency and the frontier
//! are co-partitioned by node, so the per-round join is narrow; candidate
//! labels merge into the label state via a partition-aware union plus
//! [`Dataset::reduce_values`], also narrow. The **only** shuffle each
//! round moves the (map-side combined) messages re-keyed to their
//! receiving neighbour. Convergence is an empty frontier — a metadata
//! check — replacing the naive full-dataset label-sum scan.
//! [`wcc_minispark_naive`] keeps the old every-round-full-shuffle loop as
//! the comparison baseline for `bench_wcc_frontier`.

use crate::minispark::{join_u64, Dataset, MiniSpark};
use crate::provenance::model::Trace;
use rustc_hash::FxHashMap;

/// Union-find (disjoint-set forest) over arbitrary `u64` keys, with path
/// halving and union by rank.
#[derive(Debug, Default, Clone)]
pub struct UnionFind {
    parent: FxHashMap<u64, u64>,
    rank: FxHashMap<u64, u8>,
}

impl UnionFind {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure `x` exists as a singleton.
    pub fn insert(&mut self, x: u64) {
        self.parent.entry(x).or_insert(x);
    }

    /// Root of `x`'s set (inserting `x` if new). Applies path halving.
    pub fn find(&mut self, x: u64) -> u64 {
        self.insert(x);
        let mut cur = x;
        loop {
            let p = self.parent[&cur];
            if p == cur {
                return cur;
            }
            let gp = self.parent[&p];
            self.parent.insert(cur, gp);
            cur = gp;
        }
    }

    /// Merge the sets of `a` and `b`.
    pub fn union(&mut self, a: u64, b: u64) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return;
        }
        let ka = *self.rank.entry(ra).or_insert(0);
        let kb = *self.rank.entry(rb).or_insert(0);
        if ka < kb {
            self.parent.insert(ra, rb);
        } else if ka > kb {
            self.parent.insert(rb, ra);
        } else {
            self.parent.insert(rb, ra);
            self.rank.insert(ra, ka + 1);
        }
    }

    /// All keys ever inserted.
    pub fn keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.parent.keys().copied()
    }

    /// Group every inserted key by its set: `root → members`. One `find`
    /// per key (path halving keeps later finds O(1)). This is how the
    /// sharded ingest front (`harness::ShardedSession`) resolves which
    /// batch triples — and which existing components they drag in — belong
    /// to one merge group.
    pub fn groups(&mut self) -> FxHashMap<u64, Vec<u64>> {
        let keys: Vec<u64> = self.keys().collect();
        let mut out: FxHashMap<u64, Vec<u64>> = FxHashMap::default();
        for k in keys {
            let r = self.find(k);
            out.entry(r).or_default().push(k);
        }
        out
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Normalize to `node → min-id-in-component` labels.
    ///
    /// One `find` per key: roots are resolved once up front, then reused
    /// for both the per-root minimum and the final label map (the second
    /// `find` pass the old implementation paid is gone — after path
    /// halving the root is stable, so caching it is sound).
    pub fn min_labels(&mut self) -> FxHashMap<u64, u64> {
        let keys: Vec<u64> = self.keys().collect();
        let roots: Vec<u64> = keys.iter().map(|&k| self.find(k)).collect();
        let mut min_of_root: FxHashMap<u64, u64> = FxHashMap::default();
        for (&k, &r) in keys.iter().zip(&roots) {
            min_of_root.entry(r).and_modify(|m| *m = (*m).min(k)).or_insert(k);
        }
        keys.iter().zip(&roots).map(|(&k, &r)| (k, min_of_root[&r])).collect()
    }
}

/// Outcome of one [`LabeledUnion::union`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Merge {
    /// Label of the surviving (larger) component.
    pub winner: u64,
    /// Label of the component absorbed into `winner` (`None` when both
    /// endpoints already shared a component).
    pub absorbed: Option<u64>,
    /// Index into `members(winner)` where the relabelled (absorbed) nodes
    /// begin — callers mirror exactly `members(winner)[relabelled_from..]`
    /// into any external label map.
    pub relabelled_from: usize,
}

impl Merge {
    /// Number of nodes whose label this union rewrote.
    pub fn relabelled(&self, members_after: usize) -> usize {
        members_after - self.relabelled_from
    }
}

/// Incrementally maintained component labelling: union-find semantics with
/// **explicit membership lists**, so merging two components rewrites only
/// the smaller side's labels (classic small-to-large; total relabel work
/// over any append sequence is `O(n log n)`).
///
/// Unlike [`wcc_driver`]'s min-id labels, a `LabeledUnion` label is *some
/// member node's id* — stable across merges of smaller components into it,
/// but not necessarily the minimum. Downstream equivalence with a
/// from-scratch labelling therefore holds **up to relabelling**; use
/// [`crate::provenance::incremental::canonical_labels`] to compare.
#[derive(Debug, Clone, Default)]
pub struct LabeledUnion {
    label_of: FxHashMap<u64, u64>,
    members: FxHashMap<u64, Vec<u64>>,
}

impl LabeledUnion {
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt an existing `node → label` map (e.g. a [`Preprocessed`]'s
    /// `cc_of`, whatever implementation produced it).
    ///
    /// [`Preprocessed`]: crate::provenance::pipeline::Preprocessed
    pub fn from_labels(labels: &FxHashMap<u64, u64>) -> Self {
        let mut lu = Self {
            label_of: labels.clone(),
            members: FxHashMap::default(),
        };
        for (&n, &l) in labels {
            lu.members.entry(l).or_default().push(n);
        }
        lu
    }

    /// Insert `x` as a singleton component; returns `true` if `x` was new.
    pub fn insert(&mut self, x: u64) -> bool {
        if self.label_of.contains_key(&x) {
            return false;
        }
        self.label_of.insert(x, x);
        self.members.insert(x, vec![x]);
        true
    }

    /// Current label of `x`, if known.
    pub fn label(&self, x: u64) -> Option<u64> {
        self.label_of.get(&x).copied()
    }

    /// Members of the component labelled `label` (empty if unknown).
    pub fn members(&self, label: u64) -> &[u64] {
        self.members.get(&label).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Union the components of `a` and `b` (both inserted if new). The
    /// side with fewer members is relabelled and appended to the winner's
    /// member list; see [`Merge`].
    pub fn union(&mut self, a: u64, b: u64) -> Merge {
        self.insert(a);
        self.insert(b);
        let la = self.label_of[&a];
        let lb = self.label_of[&b];
        if la == lb {
            return Merge {
                winner: la,
                absorbed: None,
                relabelled_from: self.members[&la].len(),
            };
        }
        let (winner, loser) =
            if self.members[&la].len() >= self.members[&lb].len() { (la, lb) } else { (lb, la) };
        let moved = self.members.remove(&loser).expect("loser has members");
        for &n in &moved {
            self.label_of.insert(n, winner);
        }
        let wv = self.members.get_mut(&winner).expect("winner has members");
        let relabelled_from = wv.len();
        wv.extend(moved);
        Merge { winner, absorbed: Some(loser), relabelled_from }
    }

    /// Number of distinct components.
    pub fn component_count(&self) -> usize {
        self.members.len()
    }

    /// Number of known nodes.
    pub fn len(&self) -> usize {
        self.label_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.label_of.is_empty()
    }

    /// The full `node → label` map (borrow; for canonicalization/tests).
    pub fn labels(&self) -> &FxHashMap<u64, u64> {
        &self.label_of
    }
}

/// Driver-side WCC: union-find over all triples. Returns
/// `node → min-id-in-component`.
///
/// Perf note (EXPERIMENTS.md §Perf, L3-1): ids are first remapped to dense
/// indices in ascending raw order, so the union-find runs over flat `Vec`s
/// (path halving + union by rank) instead of hash maps — ~4× faster than
/// the generic [`UnionFind`] on the default trace. Ascending order also
/// makes "min raw id per component" a first-seen scan.
pub fn wcc_driver(trace: &Trace) -> FxHashMap<u64, u64> {
    // Dense remap, ascending by raw id.
    let mut raw_of: Vec<u64> = Vec::with_capacity(trace.triples.len() * 2);
    for t in &trace.triples {
        raw_of.push(t.src.raw());
        raw_of.push(t.dst.raw());
    }
    raw_of.sort_unstable();
    raw_of.dedup();
    let dense_of: FxHashMap<u64, u32> =
        raw_of.iter().enumerate().map(|(i, &r)| (r, i as u32)).collect();

    let n = raw_of.len();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    let mut rank: Vec<u8> = vec![0; n];

    #[inline]
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        loop {
            let p = parent[x as usize];
            if p == x {
                return x;
            }
            let gp = parent[p as usize];
            parent[x as usize] = gp; // path halving
            x = gp;
        }
    }

    for t in &trace.triples {
        let a = find(&mut parent, dense_of[&t.src.raw()]);
        let b = find(&mut parent, dense_of[&t.dst.raw()]);
        if a == b {
            continue;
        }
        let (ra, rb) = (rank[a as usize], rank[b as usize]);
        if ra < rb {
            parent[a as usize] = b;
        } else if ra > rb {
            parent[b as usize] = a;
        } else {
            parent[b as usize] = a;
            rank[a as usize] = ra + 1;
        }
    }

    // Min raw id per root: dense indices ascend with raw ids, so the first
    // index seen for a root is the component minimum.
    let mut min_of_root: Vec<u32> = vec![u32::MAX; n];
    let mut labels: FxHashMap<u64, u64> =
        FxHashMap::with_capacity_and_hasher(n, Default::default());
    for i in 0..n as u32 {
        let r = find(&mut parent, i) as usize;
        if min_of_root[r] == u32::MAX {
            min_of_root[r] = i;
        }
        labels.insert(raw_of[i as usize], raw_of[min_of_root[r] as usize]);
    }
    labels
}

/// Distributed WCC by frontier-based (delta) min-label propagation on
/// minispark. See the module docs for the algorithm; returns the same
/// `node → min-id-in-component` map as [`wcc_driver`].
pub fn wcc_minispark(sc: &MiniSpark, trace: &Trace, num_partitions: usize) -> FxHashMap<u64, u64> {
    wcc_minispark_frontier(sc, trace, num_partitions).0
}

/// [`wcc_minispark`] exposing the round count (benches/tests).
pub fn wcc_minispark_frontier(
    sc: &MiniSpark,
    trace: &Trace,
    num_partitions: usize,
) -> (FxHashMap<u64, u64>, usize) {
    let np = num_partitions.max(1);
    if trace.is_empty() {
        return (FxHashMap::default(), 0);
    }
    let rows: Vec<(u64, u64)> =
        trace.triples.iter().map(|t| (t.src.raw(), t.dst.raw())).collect();
    let edges = Dataset::from_vec(sc, rows, np);
    // Undirected adjacency (both directions), co-partitioned by node.
    let adj = edges.flat_map(|&(s, d)| vec![(s, d), (d, s)]).partition_by_key(np).cache();

    // Initial labels: every node labels itself.
    let mut labels = edges
        .flat_map(|&(s, d)| vec![(s, s), (d, d)])
        .reduce_by_key(np, |&(n, l)| (n, l), u64::min);

    // Round 0: every node's label just "changed" (to itself), so the whole
    // label set is the first frontier.
    let mut frontier = labels.clone();
    let mut rounds = 0;
    while !frontier.is_empty() {
        rounds += 1;
        // Push changed labels across edges: `adj ⋈ frontier` is narrow
        // (both sides key-partitioned to np); re-keying each message to
        // its receiving neighbour is the round's only shuffle —
        // O(edges incident to the frontier), map-side combined.
        let msgs = join_u64(&adj, &frontier, np).map(|&(_, (nbr, l))| (nbr, l));
        let cand = msgs.reduce_by_key(np, |&(n, l)| (n, l), u64::min);
        // Keep only strict improvements; the inner join drops nodes that
        // received no message. Candidates are the (small) build side; the
        // label state is only probed. `map_values` keeps the
        // key-partitioning.
        let improved = join_u64(&labels, &cand, np)
            .filter(|&(_, (old, new))| new < old)
            .map_values(|&(_, new)| new);
        // Merge improvements into the label state: partition-aware union +
        // narrow per-partition reduce — zero rows moved.
        labels = labels.union(&improved).reduce_values(np, u64::min);
        frontier = improved;
    }
    (labels.collect().into_iter().collect(), rounds)
}

/// The pre-frontier baseline: every round re-broadcasts **all** labels
/// across **all** edges and re-reduces the full label set, detecting
/// convergence with a full label-sum scan. Kept for `bench_wcc_frontier`
/// and the equivalence property tests; use [`wcc_minispark`] everywhere
/// else. Returns `(labels, rounds)`.
pub fn wcc_minispark_naive(
    sc: &MiniSpark,
    trace: &Trace,
    num_partitions: usize,
) -> (FxHashMap<u64, u64>, usize) {
    let np = num_partitions.max(1);
    if trace.is_empty() {
        return (FxHashMap::default(), 0);
    }
    let rows: Vec<(u64, u64)> =
        trace.triples.iter().map(|t| (t.src.raw(), t.dst.raw())).collect();
    let edges = Dataset::from_vec(sc, rows, np);
    let adj = edges.flat_map(|&(s, d)| vec![(s, d), (d, s)]).partition_by_key(np).cache();

    let mut labels = edges
        .flat_map(|&(s, d)| vec![(s, s), (d, d)])
        .reduce_by_key(np, |&(n, l)| (n, l), u64::min);

    let label_sum = |ls: &Dataset<(u64, u64)>| -> u128 {
        ls.map_partitions(|p| vec![p.iter().map(|&(_, l)| l as u128).sum::<u128>()])
            .collect()
            .into_iter()
            .sum()
    };

    let mut rounds = 0;
    let mut prev_sum = label_sum(&labels);
    loop {
        rounds += 1;
        // (node, (nbr, label)) → messages (nbr, label); min-reduce with
        // the current labels so labels never increase.
        let msgs = join_u64(&adj, &labels, np).map(|&(_, (nbr, l))| (nbr, l));
        labels = labels
            .union(&msgs.partition_by_key(np))
            .reduce_by_key(np, |&(n, l)| (n, l), u64::min);
        let sum = label_sum(&labels);
        if sum == prev_sum {
            break;
        }
        prev_sum = sum;
    }
    (labels.collect().into_iter().collect(), rounds)
}

/// Group nodes by label: `component min-id → nodes`.
pub fn components_from_labels(labels: &FxHashMap<u64, u64>) -> FxHashMap<u64, Vec<u64>> {
    let mut out: FxHashMap<u64, Vec<u64>> = FxHashMap::default();
    for (&n, &l) in labels {
        out.entry(l).or_default().push(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::provenance::model::ProvTriple;
    use crate::util::ids::{AttrValueId, EntityId, OpId};

    fn av(e: u16, s: u64) -> AttrValueId {
        AttrValueId::new(EntityId(e), s)
    }

    fn trace(edges: &[(u64, u64)]) -> Trace {
        Trace::new(
            edges
                .iter()
                .map(|&(s, d)| ProvTriple::new(av(0, s), av(1, d), OpId(0)))
                .collect(),
        )
    }

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn union_find_basic() {
        let mut uf = UnionFind::new();
        uf.union(1, 2);
        uf.union(3, 4);
        assert_ne!(uf.find(1), uf.find(3));
        uf.union(2, 3);
        assert_eq!(uf.find(1), uf.find(4));
        assert_eq!(uf.len(), 4);
    }

    #[test]
    fn min_labels_are_component_minima() {
        let mut uf = UnionFind::new();
        uf.union(10, 5);
        uf.union(5, 7);
        uf.union(100, 200);
        uf.insert(42);
        let labels = uf.min_labels();
        assert_eq!(labels[&10], 5);
        assert_eq!(labels[&7], 5);
        assert_eq!(labels[&200], 100);
        assert_eq!(labels[&42], 42);
    }

    #[test]
    fn driver_wcc_two_components() {
        // Note av(0,s) and av(1,d) are distinct id spaces; edges (1,1)
        // still produce two distinct nodes.
        let t = trace(&[(1, 1), (1, 2), (3, 4)]);
        let labels = wcc_driver(&t);
        assert_eq!(labels.len(), 5); // nodes: 0:1, 0:3, 1:1, 1:2, 1:4
        let c = components_from_labels(&labels);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn minispark_matches_driver() {
        // A few structured graphs.
        for edges in [
            vec![(1u64, 1u64), (2, 1), (3, 2), (9, 9)],
            vec![(1, 1), (2, 2), (3, 3)],
            (0..50).map(|i| (i, i)).collect::<Vec<_>>(), // star-ish per id
            (0..40).map(|i| (i, i + 1)).collect::<Vec<_>>(), // overlapping chain
        ] {
            let t = trace(&edges);
            let a = wcc_driver(&t);
            let b = wcc_minispark(&sc(), &t, 4);
            assert_eq!(a, b, "edges={edges:?}");
        }
    }

    #[test]
    fn frontier_equals_naive_and_shuffles_less() {
        let edges: Vec<(u64, u64)> = (0..60).map(|i| (i, i + 1)).collect();
        let t = trace(&edges);
        let s = sc();

        let before = s.metrics().snapshot();
        let (naive, naive_rounds) = wcc_minispark_naive(&s, &t, 4);
        let naive_shuffled = s.metrics().snapshot().since(&before).rows_shuffled;

        let before = s.metrics().snapshot();
        let (frontier, frontier_rounds) = wcc_minispark_frontier(&s, &t, 4);
        let frontier_shuffled = s.metrics().snapshot().since(&before).rows_shuffled;

        assert_eq!(naive, frontier);
        assert_eq!(frontier, wcc_driver(&t));
        assert!(naive_rounds >= 1 && frontier_rounds >= 1);
        assert!(
            frontier_shuffled < naive_shuffled,
            "frontier shuffled {frontier_shuffled} rows, naive {naive_shuffled}"
        );
    }

    #[test]
    fn labeled_union_small_side_relabels() {
        let mut lu = LabeledUnion::new();
        // Build a 3-node component {1,2,3} and a singleton {9}.
        lu.union(1, 2);
        lu.union(2, 3);
        assert_eq!(lu.component_count(), 1);
        let big = lu.label(1).unwrap();
        assert_eq!(lu.members(big).len(), 3);
        lu.insert(9);
        assert_eq!(lu.component_count(), 2);
        // Merging the singleton in relabels exactly one node — the smaller
        // side — and the big component's label survives.
        let m = lu.union(9, 3);
        assert_eq!(m.winner, big);
        assert_eq!(m.absorbed, Some(9));
        assert_eq!(m.relabelled(lu.members(big).len()), 1);
        assert_eq!(lu.label(9), Some(big));
        assert_eq!(lu.component_count(), 1);
        // Unioning within one component is a no-op.
        let m = lu.union(1, 9);
        assert_eq!(m.absorbed, None);
        assert_eq!(m.relabelled(lu.members(big).len()), 0);
    }

    #[test]
    fn labeled_union_from_labels_roundtrip() {
        let t = trace(&[(1, 1), (1, 2), (3, 4)]);
        let labels = wcc_driver(&t);
        let lu = LabeledUnion::from_labels(&labels);
        assert_eq!(lu.labels(), &labels);
        assert_eq!(lu.len(), labels.len());
        let c = components_from_labels(&labels);
        assert_eq!(lu.component_count(), c.len());
        for (&l, nodes) in &c {
            let mut got: Vec<u64> = lu.members(l).to_vec();
            let mut want = nodes.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want);
        }
        assert!(lu.members(u64::MAX).is_empty());
    }

    #[test]
    fn empty_trace_empty_labels() {
        let t = Trace::default();
        assert!(wcc_driver(&t).is_empty());
        assert!(wcc_minispark(&sc(), &t, 4).is_empty());
        assert!(wcc_minispark_naive(&sc(), &t, 4).0.is_empty());
    }

    #[test]
    fn union_find_groups_partition_the_keys() {
        let mut uf = UnionFind::new();
        uf.union(1, 2);
        uf.union(2, 3);
        uf.union(10, 11);
        uf.insert(99);
        let groups = uf.groups();
        assert_eq!(groups.len(), 3);
        let total: usize = groups.values().map(|v| v.len()).sum();
        assert_eq!(total, 6);
        let of = |n: u64| {
            groups
                .iter()
                .find(|(_, v)| v.contains(&n))
                .map(|(&r, _)| r)
                .expect("member present")
        };
        assert_eq!(of(1), of(3));
        assert_eq!(of(10), of(11));
        assert_ne!(of(1), of(10));
        assert_ne!(of(99), of(1));
    }
}
