//! A small work-stealing-free thread pool and scoped parallel-map helpers.
//!
//! The offline build has no `tokio`/`rayon`; the engine is CPU-bound, so a
//! fixed pool of OS threads with an injector queue is the right substrate
//! anyway. [`ThreadPool`] executes boxed jobs; [`par_map_indexed`] runs a
//! closure over a slice of inputs with bounded parallelism and preserves
//! input order in the output; [`par_map_supervised`] is the fault-tolerant
//! variant — per-task `catch_unwind`, typed [`TaskError`]s, and
//! [`RetryPolicy`]-driven retries before a task is quarantined.

mod pool;

pub use pool::{
    panic_message, par_map_indexed, par_map_supervised, RetryPolicy, SupervisionStats,
    TaskError, ThreadPool,
};
