//! A small work-stealing-free thread pool and scoped parallel-map helpers.
//!
//! The offline build has no `tokio`/`rayon`; the engine is CPU-bound, so a
//! fixed pool of OS threads with an injector queue is the right substrate
//! anyway. [`ThreadPool`] executes boxed jobs; [`par_map_indexed`] runs a
//! closure over a slice of inputs with bounded parallelism and preserves
//! input order in the output.

mod pool;

pub use pool::{par_map_indexed, ThreadPool};
