//! Fixed-size thread pool with a shared FIFO injector queue, plus the
//! supervised parallel-map substrate: every task attempt runs under
//! `catch_unwind`, panics become typed [`TaskError`]s, and a
//! [`RetryPolicy`] re-runs failed tasks with capped exponential backoff
//! before quarantining them.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    outstanding: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
}

/// A fixed pool of worker threads executing FIFO jobs.
///
/// `minispark`'s executors submit one job per task; the pool size models
/// the cluster's total core count (configurable — the paper uses
/// 8 nodes × 12 cores; this box has fewer, so parallelism is logical).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size >= 1` worker threads.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            outstanding: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("provspark-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job for asynchronous execution.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.outstanding.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                if shared.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_mx.lock().unwrap();
                    shared.idle_cv.notify_all();
                }
            }
            None => return,
        }
    }
}

/// One supervised task's terminal failure: every attempt the
/// [`RetryPolicy`] allowed panicked, and the task was quarantined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskError {
    /// Input index of the failed task.
    pub index: usize,
    /// How many attempts were made (first run + retries).
    pub attempts: u32,
    /// Panic message of the final attempt.
    pub message: String,
}

impl fmt::Display for TaskError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "task {} failed after {} attempt{}: {}",
            self.index,
            self.attempts,
            if self.attempts == 1 { "" } else { "s" },
            self.message
        )
    }
}

impl std::error::Error for TaskError {}

/// Retry schedule for supervised tasks: up to `max_attempts` runs, with
/// capped exponential backoff (`backoff`, `2·backoff`, `4·backoff`, … up
/// to `backoff_cap`) between consecutive attempts of the same task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    pub backoff: Duration,
    pub backoff_cap: Duration,
}

impl RetryPolicy {
    /// `retries` extra attempts after the first, backing off from
    /// `backoff` and capping at `32·backoff`.
    pub fn new(retries: u32, backoff: Duration) -> Self {
        Self {
            max_attempts: retries.saturating_add(1),
            backoff,
            backoff_cap: backoff.saturating_mul(32),
        }
    }

    /// Single attempt, no backoff — the unsupervised contract.
    pub fn no_retry() -> Self {
        Self { max_attempts: 1, backoff: Duration::ZERO, backoff_cap: Duration::ZERO }
    }

    /// Sleep before attempt `failures + 1` (exponential in the number of
    /// failures so far, capped).
    fn delay(&self, failures: u32) -> Duration {
        let mult = 1u32 << failures.saturating_sub(1).min(16);
        self.backoff.saturating_mul(mult).min(self.backoff_cap)
    }
}

/// Tally of one supervised fan-out.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SupervisionStats {
    /// Task attempts re-run after a caught panic.
    pub retries: u64,
    /// Tasks that exhausted every attempt (their slot holds an `Err`).
    pub quarantined: u64,
}

/// Run `f(i, &items[i])` for every element with at most `parallelism`
/// threads, each attempt under `catch_unwind`, retrying per `policy`.
/// Outputs come back in input order; a task that exhausts its attempts
/// yields `Err(TaskError)` in its slot instead of poisoning the fan-out.
///
/// Robustness contract: a panicking task can neither kill its worker
/// thread nor hang the collection — the panic is caught *inside* the
/// claim loop, so the worker lives on to claim the remaining slice, and
/// every slot is filled with `Ok` or `Err` before this returns.
///
/// Uses `std::thread::scope` (no `'static` bound on inputs or closure;
/// no external scoped-thread crate — the build is offline).
pub fn par_map_supervised<T, U, F>(
    items: &[T],
    parallelism: usize,
    policy: &RetryPolicy,
    f: F,
) -> (Vec<Result<U, TaskError>>, SupervisionStats)
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return (Vec::new(), SupervisionStats::default());
    }
    let parallelism = parallelism.clamp(1, n);
    let retries = AtomicU64::new(0);
    let quarantined = AtomicU64::new(0);
    let max_attempts = policy.max_attempts.max(1);
    let run_one = |i: usize| -> Result<U, TaskError> {
        let mut failures = 0u32;
        loop {
            match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                Ok(v) => return Ok(v),
                Err(payload) => {
                    failures += 1;
                    if failures >= max_attempts {
                        quarantined.fetch_add(1, Ordering::Relaxed);
                        return Err(TaskError {
                            index: i,
                            attempts: failures,
                            message: panic_message(payload.as_ref()),
                        });
                    }
                    retries.fetch_add(1, Ordering::Relaxed);
                    let d = policy.delay(failures);
                    if !d.is_zero() {
                        std::thread::sleep(d);
                    }
                }
            }
        }
    };
    let out: Vec<Result<U, TaskError>> = if parallelism == 1 {
        (0..n).map(run_one).collect()
    } else {
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<Result<U, TaskError>>> = (0..n).map(|_| None).collect();
        let out_ptr = SendPtr(slots.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..parallelism {
                scope.spawn(|| {
                    let out_ptr = &out_ptr;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let v = run_one(i);
                        // SAFETY: each index i is claimed exactly once via
                        // the atomic counter, so no two threads write the
                        // same slot, and the Vec outlives the scope.
                        unsafe { *out_ptr.0.add(i) = Some(v) };
                    }
                });
            }
        });
        slots.into_iter().map(|v| v.expect("every claimed slot is filled")).collect()
    };
    let stats = SupervisionStats {
        retries: retries.load(Ordering::Relaxed),
        quarantined: quarantined.load(Ordering::Relaxed),
    };
    (out, stats)
}

/// Extract a readable message from a caught panic payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked with a non-string payload".to_string()
    }
}

/// Run `f(i, &items[i])` for every element with at most `parallelism`
/// threads, returning outputs in input order. A panic in `f` fails the
/// whole map: it re-surfaces (carrying the [`TaskError`] message) after
/// every other task finished — workers are never torn down mid-slice.
///
/// This is the fan-out substrate behind both `MiniSpark::run_job` and
/// `ProvSession::query_many`; callers wanting per-task errors and retries
/// use [`par_map_supervised`] directly.
pub fn par_map_indexed<T, U, F>(items: &[T], parallelism: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let (out, _) = par_map_supervised(items, parallelism, &RetryPolicy::no_retry(), f);
    out.into_iter()
        .map(|r| match r {
            Ok(v) => v,
            Err(e) => panic!("{e}"),
        })
        .collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-write pattern above.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map_indexed(&items, 8, |i, &x| x * 2 + i as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_indexed(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map_indexed(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_parallelism_one_sequential() {
        let items: Vec<u32> = (0..10).collect();
        let out = par_map_indexed(&items, 1, |_, &x| x + 1);
        assert_eq!(out, (1..11).collect::<Vec<_>>());
    }

    /// Silence the default panic hook while injected panics fly; restores
    /// the previous hook on drop. Tests using it run single-file via the
    /// mutex so they cannot unhook each other.
    struct QuietPanics {
        _guard: std::sync::MutexGuard<'static, ()>,
    }

    static HOOK_MX: Mutex<()> = Mutex::new(());

    impl QuietPanics {
        fn new() -> Self {
            let guard = HOOK_MX.lock().unwrap_or_else(|e| e.into_inner());
            std::panic::set_hook(Box::new(|_| {}));
            Self { _guard: guard }
        }
    }

    impl Drop for QuietPanics {
        fn drop(&mut self) {
            let _ = std::panic::take_hook();
        }
    }

    #[test]
    fn supervised_retries_clear_a_transient_panic() {
        let _quiet = QuietPanics::new();
        let items: Vec<u32> = (0..64).collect();
        let failed_once = AtomicU64::new(0);
        let policy = RetryPolicy::new(2, Duration::from_micros(50));
        let (out, stats) = par_map_supervised(&items, 8, &policy, |i, &x| {
            // Index 13 panics on its first attempt only.
            if i == 13 && failed_once.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("transient fault");
            }
            x * 2
        });
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), items[i] * 2);
        }
        assert_eq!(stats.retries, 1);
        assert_eq!(stats.quarantined, 0);
    }

    #[test]
    fn supervised_quarantines_a_persistent_panic_without_hanging() {
        let _quiet = QuietPanics::new();
        let items: Vec<u32> = (0..32).collect();
        let policy = RetryPolicy::new(2, Duration::ZERO);
        let (out, stats) = par_map_supervised(&items, 4, &policy, |i, &x| {
            if i == 7 {
                panic!("hard fault at {i}");
            }
            x + 1
        });
        // The sick task's worker survived and finished the rest of the
        // slice: every other slot is Ok.
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                let e = r.as_ref().unwrap_err();
                assert_eq!(e.index, 7);
                assert_eq!(e.attempts, 3);
                assert!(e.message.contains("hard fault"), "{e}");
                assert!(e.to_string().contains("after 3 attempts"), "{e}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), items[i] + 1);
            }
        }
        assert_eq!(stats.retries, 2);
        assert_eq!(stats.quarantined, 1);
    }

    #[test]
    fn par_map_indexed_propagates_a_task_panic() {
        let _quiet = QuietPanics::new();
        let items: Vec<u32> = (0..16).collect();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            par_map_indexed(&items, 4, |i, &x| {
                if i == 3 {
                    panic!("boom");
                }
                x
            })
        }));
        let msg = panic_message(caught.unwrap_err().as_ref());
        assert!(msg.contains("task 3 failed after 1 attempt: boom"), "{msg}");
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = RetryPolicy::new(10, Duration::from_micros(100));
        assert_eq!(p.delay(1), Duration::from_micros(100));
        assert_eq!(p.delay(2), Duration::from_micros(200));
        assert_eq!(p.delay(3), Duration::from_micros(400));
        assert_eq!(p.delay(20), p.backoff_cap);
        assert_eq!(p.backoff_cap, Duration::from_micros(3200));
    }
}
