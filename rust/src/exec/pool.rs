//! Fixed-size thread pool with a shared FIFO injector queue.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    outstanding: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
}

/// A fixed pool of worker threads executing FIFO jobs.
///
/// `minispark`'s executors submit one job per task; the pool size models
/// the cluster's total core count (configurable — the paper uses
/// 8 nodes × 12 cores; this box has fewer, so parallelism is logical).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size >= 1` worker threads.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            outstanding: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("provspark-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job for asynchronous execution.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.outstanding.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                if shared.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_mx.lock().unwrap();
                    shared.idle_cv.notify_all();
                }
            }
            None => return,
        }
    }
}

/// Run `f(i, &items[i])` for every element with at most `parallelism`
/// threads, returning outputs in input order. Panics in `f` propagate.
///
/// Uses `std::thread::scope` (no `'static` bound on inputs or closure;
/// no external scoped-thread crate — the build is offline). This is the
/// fan-out substrate behind both `MiniSpark::run_job` and
/// `ProvSession::query_many`.
pub fn par_map_indexed<T, U, F>(items: &[T], parallelism: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let parallelism = parallelism.clamp(1, n);
    if parallelism == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..parallelism {
            scope.spawn(|| {
                let out_ptr = &out_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i, &items[i]);
                    // SAFETY: each index i is claimed exactly once via the
                    // atomic counter, so no two threads write the same slot,
                    // and the Vec outlives the scope.
                    unsafe { *out_ptr.0.add(i) = Some(v) };
                }
            });
        }
        // std scope joins all spawned threads on exit and re-panics if a
        // worker panicked — the propagation guarantee documented above.
    });
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-write pattern above.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map_indexed(&items, 8, |i, &x| x * 2 + i as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_indexed(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map_indexed(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_parallelism_one_sequential() {
        let items: Vec<u32> = (0..10).collect();
        let out = par_map_indexed(&items, 1, |_, &x| x + 1);
        assert_eq!(out, (1..11).collect::<Vec<_>>());
    }
}
