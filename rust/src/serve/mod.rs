//! The multi-tenant serving front: the paper's engines as a service.
//!
//! Everything below this module ends at a library call; this layer turns
//! the sharded query/ingest surface into something many independent
//! clients can hit concurrently with bounded latency:
//!
//! * **Admission control** ([`AdmissionController`]) — per-tenant
//!   token-bucket quotas plus a bounded in-flight queue; refusals are
//!   typed [`Rejected`] answers, never silent drops.
//! * **Micro-batching** ([`ServeFront`]) — concurrent point queries
//!   arriving within [`ServeConfig::window`] coalesce into one
//!   `query_many` scatter-gather; per-request `QueryStats` attribution is
//!   preserved, and identical requests in one window execute once.
//! * **Epoch-keyed result cache** ([`ResultCache`]) — `(epoch, item,
//!   normalized options) → Lineage`; ingest sweeps only the dirty
//!   components' entries, so unrelated cached answers survive the epoch
//!   swap and a warm hit does zero engine scans.
//! * **Streaming partial answers** — a deadline-bounded request is
//!   answered immediately with the provable lineage prefix plus its
//!   honest `Completeness` bound; the full answer completes on a
//!   background pool, streams as a second response, and lands in the
//!   cache.
//!
//! Built entirely on the existing `exec` thread pool and std channels —
//! no async runtime.

mod admission;
mod cache;
mod front;

pub use admission::{AdmissionController, Rejected};
pub use cache::{CacheKey, ResultCache};
pub use front::{ServeFront, ServeResponse, TicketHandle};

use crate::harness::ShardBatchStats;
use std::sync::atomic::AtomicU64;
use std::time::Duration;

/// Tuning for a [`ServeFront`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Micro-batch window: how long the batcher waits after the first
    /// ticket for more to coalesce. Zero disables coalescing.
    pub window: Duration,
    /// Max tickets per window (the window closes early when reached).
    pub window_max: usize,
    /// Bound on requests in flight (admitted, not yet first-answered).
    pub queue_capacity: usize,
    /// Per-tenant refill rate in requests/second; `f64::INFINITY`
    /// disables quotas, `0.0` means the burst is all a tenant gets.
    pub quota_qps: f64,
    /// Per-tenant token-bucket capacity (burst size).
    pub quota_burst: f64,
    /// Complete deadline-cut answers in the background (second streamed
    /// response + cache fill). Off means partials stay partial.
    pub complete_partials: bool,
    /// Threads finishing deadline-cut answers.
    pub completion_workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            window: Duration::from_millis(2),
            window_max: 64,
            queue_capacity: 1024,
            quota_qps: f64::INFINITY,
            quota_burst: 32.0,
            complete_partials: true,
            completion_workers: 2,
        }
    }
}

/// Internal serving counters (atomics; snapshot via
/// [`ServeFront::report`]).
#[derive(Debug, Default)]
pub(crate) struct ServeMetrics {
    pub(crate) admitted: AtomicU64,
    pub(crate) rejected_quota: AtomicU64,
    pub(crate) rejected_queue: AtomicU64,
    pub(crate) windows: AtomicU64,
    pub(crate) coalesced: AtomicU64,
    pub(crate) deduped: AtomicU64,
    pub(crate) partials_served: AtomicU64,
    pub(crate) completions: AtomicU64,
}

/// Snapshot of everything the front has done: admission decisions, window
/// coalescing, cache traffic, partial-answer streaming, and the
/// accumulated per-shard execution stats.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub admitted: u64,
    pub rejected_quota: u64,
    pub rejected_queue: u64,
    /// Micro-batch windows processed.
    pub windows: u64,
    /// Requests that shared a window with at least one other request.
    pub coalesced: u64,
    /// Requests answered by another identical request in the same window.
    pub deduped: u64,
    /// Deadline-cut partial answers streamed out.
    pub partials_served: u64,
    /// Background completions finished.
    pub completions: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_inserts: u64,
    /// Inserts refused because an ingest moved the epoch mid-query.
    pub cache_stale_inserts: u64,
    /// Entries dropped by ingest sweeps.
    pub cache_invalidations: u64,
    /// Entries resident right now.
    pub cache_entries: usize,
    /// Requests admitted but not yet first-answered right now.
    pub in_flight: usize,
    /// Lifetime per-shard aggregate of executed + cache-served requests
    /// (same shape as one `ShardedBatchReport`, accumulated).
    pub per_shard: Vec<ShardBatchStats>,
}

impl ServeReport {
    /// Collapse the per-shard aggregate into one row.
    pub fn total(&self) -> ShardBatchStats {
        let mut t = ShardBatchStats::default();
        for s in &self.per_shard {
            t.merge(s);
        }
        t
    }

    /// One-line rendering for CLI / bench output.
    pub fn summary(&self) -> String {
        let t = self.total();
        format!(
            "serve: admitted={} rejected(quota={} queue={}) windows={} coalesced={} \
             deduped={} cache(hit={} miss={} insert={} stale={} inval={} live={}) \
             partials={} completions={} | exec: {}",
            self.admitted,
            self.rejected_quota,
            self.rejected_queue,
            self.windows,
            self.coalesced,
            self.deduped,
            self.cache_hits,
            self.cache_misses,
            self.cache_inserts,
            self.cache_stale_inserts,
            self.cache_invalidations,
            self.cache_entries,
            self.partials_served,
            self.completions,
            t.summary(),
        )
    }
}
