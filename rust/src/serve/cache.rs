//! Epoch-keyed result cache: `(epoch, item, normalized options) → Lineage`.
//!
//! The cache lives above the engines, so a warm hit costs one map lookup
//! and zero engine scans. Correctness under ingest comes from two rules:
//!
//! * **Insert** is guarded by the epoch captured *before* the answer was
//!   computed ([`ResultCache::insert_if_epoch`]): if an ingest bumped the
//!   epoch while the query ran, the answer may predate the new triples
//!   and is discarded instead of cached.
//! * **Invalidation** is per dirty-component set, not wholesale: on
//!   ingest the front snapshots the *pre-ingest* WCC label of every batch
//!   endpoint and sweeps only entries tagged with one of those labels
//!   (plus entries whose item was unknown at insert time but is itself a
//!   batch endpoint). Everything else survives the epoch swap untouched.
//!
//! Why the pre-ingest labels suffice: a component is structurally touched
//! by a batch only if it contains a batch endpoint, and in the
//! small-to-large label union the merge *winner keeps its label* — so
//! every post-ingest dirty component is labelled by the pre-ingest label
//! of one of its endpoints, which is exactly the set we swept. A label
//! read that races past a concurrent ingest therefore still tags the
//! entry with a label the sweep will catch.

use crate::harness::EngineRouter;
use crate::provenance::query::{Lineage, QueryRequest};
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Normalized identity of a cacheable answer: the item plus every request
/// option that changes the result. `retries` is execution policy, not
/// identity; `deadline` makes the answer depend on wall time, so
/// deadline-bounded requests are never cacheable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub item: u64,
    pub max_depth: Option<u32>,
    pub max_triples: Option<usize>,
    pub tau_override: Option<usize>,
    /// Router discriminant — `Auto` may pick a different engine than a
    /// pinned router, and engines may differ in *stats*, so answers are
    /// keyed per routing policy even though lineages agree.
    pub router: u8,
}

impl CacheKey {
    /// The key for a request, or `None` when the request is not cacheable
    /// (any deadline-bounded request: its answer is a wall-time-dependent
    /// prefix, not a function of the key).
    pub fn of(router: EngineRouter, req: &QueryRequest) -> Option<Self> {
        if req.deadline.is_some() {
            return None;
        }
        let router = match router {
            EngineRouter::Rq => 0,
            EngineRouter::CcProv => 1,
            EngineRouter::CsProv => 2,
            EngineRouter::Auto => 3,
        };
        Some(Self {
            item: req.item,
            max_depth: req.max_depth,
            max_triples: req.max_triples,
            tau_override: req.tau_override,
            router,
        })
    }
}

#[derive(Debug, Clone)]
struct Entry {
    lineage: Lineage,
    engine: &'static str,
    /// The item's WCC label when the answer was cached; `None` when the
    /// item was unknown to every shard (empty lineage cached for a
    /// nonexistent item). `None` entries are invalidated whenever their
    /// item appears as a batch endpoint.
    label: Option<u64>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Monotonic cache epoch; bumped by every [`ResultCache::invalidate`].
    epoch: u64,
    map: FxHashMap<CacheKey, Entry>,
}

/// The shared cache. One mutex guards the map *and* the epoch so that
/// "check epoch then insert" is a single atomic step; the counters are
/// plain atomics readable without the lock.
#[derive(Debug, Default)]
pub struct ResultCache {
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    stale_inserts: AtomicU64,
    invalidated: AtomicU64,
}

impl ResultCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current cache epoch. Capture it *before* computing an answer
    /// you intend to [`insert_if_epoch`](Self::insert_if_epoch).
    pub fn epoch(&self) -> u64 {
        self.inner.lock().expect("result cache lock poisoned").epoch
    }

    /// Cached entries right now.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("result cache lock poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a cacheable answer; counts a hit or miss either way.
    pub fn get(&self, key: &CacheKey) -> Option<(Lineage, &'static str)> {
        let inner = self.inner.lock().expect("result cache lock poisoned");
        match inner.map.get(key) {
            Some(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((e.lineage.clone(), e.engine))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert an answer computed while the cache was at `epoch`. Returns
    /// `false` (and caches nothing) if an invalidation has since moved the
    /// epoch on — the answer might predate triples the sweep accounted
    /// for. Epochs only grow, so there is no ABA window.
    pub fn insert_if_epoch(
        &self,
        epoch: u64,
        key: CacheKey,
        label: Option<u64>,
        engine: &'static str,
        lineage: Lineage,
    ) -> bool {
        let mut inner = self.inner.lock().expect("result cache lock poisoned");
        if inner.epoch != epoch {
            self.stale_inserts.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        inner.map.insert(key, Entry { lineage, engine, label });
        self.inserts.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Sweep after an ingest: drop every entry whose component label is in
    /// `dirty_labels`, plus label-less entries whose item is itself a
    /// batch endpoint; bump the epoch so racing inserts die. Returns how
    /// many entries were dropped.
    pub fn invalidate(&self, dirty_labels: &FxHashSet<u64>, batch_items: &FxHashSet<u64>) -> usize {
        let mut inner = self.inner.lock().expect("result cache lock poisoned");
        inner.epoch += 1;
        let before = inner.map.len();
        inner.map.retain(|key, entry| match entry.label {
            Some(l) => !dirty_labels.contains(&l),
            None => !batch_items.contains(&key.item),
        });
        let dropped = before - inner.map.len();
        self.invalidated.fetch_add(dropped as u64, Ordering::Relaxed);
        dropped
    }

    /// Drop everything and bump the epoch — the recovery path, where the
    /// affected component set is unknown.
    pub fn clear(&self) -> usize {
        let mut inner = self.inner.lock().expect("result cache lock poisoned");
        inner.epoch += 1;
        let dropped = inner.map.len();
        inner.map.clear();
        self.invalidated.fetch_add(dropped as u64, Ordering::Relaxed);
        dropped
    }

    /// Counter snapshot: `(hits, misses, inserts, stale_inserts,
    /// invalidated)`.
    pub fn counters(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.inserts.load(Ordering::Relaxed),
            self.stale_inserts.load(Ordering::Relaxed),
            self.invalidated.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(item: u64) -> CacheKey {
        CacheKey::of(EngineRouter::Auto, &QueryRequest::new(item)).unwrap()
    }

    #[test]
    fn deadline_requests_are_never_cacheable() {
        let req = QueryRequest::new(7).with_deadline(std::time::Duration::from_millis(1));
        assert_eq!(CacheKey::of(EngineRouter::Auto, &req), None);
        // …but every other option is part of the key, not a blocker.
        let req = QueryRequest::new(7).with_max_depth(3).with_tau(10).with_retries(5);
        let k = CacheKey::of(EngineRouter::CsProv, &req).unwrap();
        assert_eq!(k.max_depth, Some(3));
        assert_eq!(k.router, 2);
    }

    #[test]
    fn stale_insert_is_refused_after_invalidation() {
        let cache = ResultCache::new();
        let epoch = cache.epoch();
        cache.invalidate(&FxHashSet::default(), &FxHashSet::default());
        assert!(!cache.insert_if_epoch(epoch, key(1), Some(1), "rq", Lineage::empty(1)));
        assert!(cache.insert_if_epoch(cache.epoch(), key(1), Some(1), "rq", Lineage::empty(1)));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn invalidation_is_per_component() {
        let cache = ResultCache::new();
        let e = cache.epoch();
        cache.insert_if_epoch(e, key(1), Some(10), "rq", Lineage::empty(1));
        cache.insert_if_epoch(e, key(2), Some(20), "rq", Lineage::empty(2));
        cache.insert_if_epoch(e, key(3), None, "rq", Lineage::empty(3));
        let dirty: FxHashSet<u64> = [10u64].into_iter().collect();
        let items: FxHashSet<u64> = [3u64].into_iter().collect();
        // Dirty label 10 kills item 1; endpoint 3 kills the label-less
        // entry; the untouched component (label 20) survives.
        assert_eq!(cache.invalidate(&dirty, &items), 2);
        assert!(cache.get(&key(2)).is_some());
        assert!(cache.get(&key(1)).is_none());
        assert!(cache.get(&key(3)).is_none());
    }
}
