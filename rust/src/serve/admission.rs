//! Admission control for the serving front: per-tenant token-bucket
//! quotas plus a bounded in-flight request count.
//!
//! Every request is either admitted or answered with a typed [`Rejected`]
//! — never silently dropped. The in-flight bound counts requests between
//! admission and their *first* answer (the backpressure signal a client
//! can act on); background completions of partial answers ride free, they
//! were already paid for at admission.

use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Typed admission refusal. The serving front returns these synchronously
/// from `submit`, so a rejected tenant knows immediately — and knows
/// *why* — instead of timing out on a dropped request.
#[derive(Debug, Clone, PartialEq)]
pub enum Rejected {
    /// The bounded request queue is at capacity; retry after in-flight
    /// requests drain.
    QueueFull { occupancy: usize, capacity: usize },
    /// The tenant's token bucket is empty; `retry_after` is when the next
    /// token accrues at the configured refill rate.
    Quota { tenant: String, retry_after: Duration },
    /// The front is shutting down and accepts no new work.
    ShuttingDown,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { occupancy, capacity } => {
                write!(f, "queue full ({occupancy}/{capacity} in flight)")
            }
            Rejected::Quota { tenant, retry_after } => {
                write!(f, "tenant {tenant:?} over quota (retry after {retry_after:?})")
            }
            Rejected::ShuttingDown => f.write_str("front is shutting down"),
        }
    }
}

/// Lazy-refill token bucket: tokens accrue at `qps` per second up to
/// `burst`, and each admission spends one.
#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(burst: f64, now: Instant) -> Self {
        Self { tokens: burst, last: now }
    }

    /// Spend one token, refilling for the elapsed time first. On refusal
    /// returns how long until a whole token has accrued.
    fn try_take(&mut self, qps: f64, burst: f64, now: Instant) -> Result<(), Duration> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * qps).min(burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        let retry_after = if qps > 0.0 {
            Duration::from_secs_f64((1.0 - self.tokens) / qps)
        } else {
            // No refill configured: the burst is all this tenant ever gets.
            Duration::MAX
        };
        Err(retry_after)
    }
}

/// The front door: a bounded in-flight count shared by all tenants, plus
/// one token bucket per tenant. Both checks are cheap (one atomic + one
/// short-held map lock) — admission must never cost more than the work it
/// is gating.
pub struct AdmissionController {
    /// Tokens per second per tenant; `f64::INFINITY` disables quotas.
    qps: f64,
    /// Bucket capacity (burst size), `>= 1` whenever quotas are on.
    burst: f64,
    /// In-flight bound (admitted, not yet first-answered).
    capacity: usize,
    in_flight: AtomicUsize,
    buckets: Mutex<FxHashMap<String, TokenBucket>>,
}

impl AdmissionController {
    pub fn new(qps: f64, burst: f64, capacity: usize) -> Self {
        Self {
            qps,
            burst: burst.max(1.0),
            capacity: capacity.max(1),
            in_flight: AtomicUsize::new(0),
            buckets: Mutex::new(FxHashMap::default()),
        }
    }

    /// Admit one request for `tenant`, or say exactly why not. An admitted
    /// request holds one in-flight slot until [`release`](Self::release).
    pub fn try_admit(&self, tenant: &str) -> Result<(), Rejected> {
        // Reserve the queue slot first; quotas refund it on refusal, so
        // rejection paths never leak occupancy.
        let reserved = self.in_flight.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
            (cur < self.capacity).then_some(cur + 1)
        });
        if let Err(occupancy) = reserved {
            return Err(Rejected::QueueFull { occupancy, capacity: self.capacity });
        }
        if self.qps.is_finite() {
            let now = Instant::now();
            let mut buckets = self.buckets.lock().expect("admission bucket lock poisoned");
            let bucket = buckets
                .entry(tenant.to_string())
                .or_insert_with(|| TokenBucket::new(self.burst, now));
            if let Err(retry_after) = bucket.try_take(self.qps, self.burst, now) {
                drop(buckets);
                self.release();
                return Err(Rejected::Quota { tenant: tenant.to_string(), retry_after });
            }
        }
        Ok(())
    }

    /// Release one in-flight slot (the request got its first answer).
    pub fn release(&self) {
        let prev = self.in_flight.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "release without a matching admit");
    }

    /// Requests currently between admission and their first answer.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_quota_only_bounds_the_queue() {
        let adm = AdmissionController::new(f64::INFINITY, 1.0, 2);
        adm.try_admit("a").unwrap();
        adm.try_admit("b").unwrap();
        match adm.try_admit("c") {
            Err(Rejected::QueueFull { occupancy: 2, capacity: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        adm.release();
        adm.try_admit("c").unwrap();
        assert_eq!(adm.in_flight(), 2);
    }

    #[test]
    fn burst_exhaustion_is_a_typed_quota_rejection() {
        // qps 0: the burst of 2 is all a tenant ever gets.
        let adm = AdmissionController::new(0.0, 2.0, 100);
        adm.try_admit("t").unwrap();
        adm.try_admit("t").unwrap();
        match adm.try_admit("t") {
            Err(Rejected::Quota { tenant, retry_after }) => {
                assert_eq!(tenant, "t");
                assert_eq!(retry_after, Duration::MAX);
            }
            other => panic!("expected Quota, got {other:?}"),
        }
        // Quota refusal refunded the queue slot…
        assert_eq!(adm.in_flight(), 2);
        // …and other tenants have their own buckets.
        adm.try_admit("u").unwrap();
    }

    #[test]
    fn tokens_refill_over_time() {
        let mut b = TokenBucket::new(1.0, Instant::now());
        let t0 = Instant::now();
        b.try_take(10.0, 1.0, t0).unwrap();
        assert!(b.try_take(10.0, 1.0, t0).is_err());
        // 200 ms at 10 tokens/s accrues 2 tokens, capped at burst 1.
        b.try_take(10.0, 1.0, t0 + Duration::from_millis(200)).unwrap();
        let Err(retry) = b.try_take(10.0, 1.0, t0 + Duration::from_millis(200)) else {
            panic!("bucket must be empty again");
        };
        assert!(retry <= Duration::from_millis(100));
    }
}
