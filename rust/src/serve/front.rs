//! The serving front: admission → micro-batch window → scatter → cache.
//!
//! A dedicated batcher thread owns the request queue. It blocks on the
//! first ticket, then keeps collecting until the window closes (elapsed
//! [`ServeConfig::window`] or [`ServeConfig::window_max`] tickets) and
//! processes the whole window at once:
//!
//! 1. cache hits answer immediately — zero engine scans;
//! 2. identical cacheable requests deduplicate to one execution;
//! 3. the survivors run as **one** [`ShardedSession::query_many_report_on`]
//!    scatter-gather, per-request `QueryStats` preserved;
//! 4. deadline-cut partial answers stream out first, and the full answer
//!    is completed on a small background pool and lands in the cache.
//!
//! Failures stay per-ticket: the sharded batch path supervises each item,
//! so a panicking engine or a faulted segment read yields one `Failed`
//! outcome on one reply channel — the window, the cache (`Failed` is
//! never cached), and the other tenants never see it.

use super::admission::{AdmissionController, Rejected};
use super::cache::{CacheKey, ResultCache};
use super::{ServeConfig, ServeMetrics, ServeReport};
use crate::exec::ThreadPool;
use crate::harness::{ShardBatchStats, ShardedBatchReport, ShardedDeltaStats, ShardedSession};
use crate::harness::EngineRouter;
use crate::provenance::query::{QueryOutcome, QueryRequest, QueryResponse, QueryStats};
use crate::provenance::TripleBatch;
use anyhow::Result;
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One answer on a ticket's reply channel. A request gets exactly one
/// response — except a deadline-cut partial, which gets the partial first
/// (`completed: false`) and the background-completed full answer second
/// (`completed: true`).
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub tenant: String,
    pub response: QueryResponse,
    pub outcome: QueryOutcome,
    /// Served straight from the result cache (zero engine scans; also
    /// marked on `response.stats.served_from_cache`).
    pub from_cache: bool,
    /// How many requests shared this micro-batch window.
    pub window_size: usize,
    /// `true` only on the second, background-completed answer to a
    /// deadline-cut request.
    pub completed: bool,
}

/// Client-side handle for one admitted request.
pub struct TicketHandle {
    rx: Receiver<ServeResponse>,
}

impl TicketHandle {
    /// Block for the next answer; `None` once the front has shut down and
    /// every answer for this ticket is delivered.
    pub fn recv(&self) -> Option<ServeResponse> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<ServeResponse> {
        self.rx.recv_timeout(timeout).ok()
    }
}

struct Ticket {
    tenant: String,
    req: QueryRequest,
    reply: Sender<ServeResponse>,
}

/// Shared state between the public handle, the batcher thread, and the
/// background completion workers.
struct Core {
    session: Arc<ShardedSession>,
    router: EngineRouter,
    cfg: ServeConfig,
    admission: AdmissionController,
    cache: ResultCache,
    metrics: ServeMetrics,
    /// Lifetime per-shard aggregate of everything the front executed or
    /// served from cache (the sharded batch report, accumulated).
    agg: Mutex<Vec<ShardBatchStats>>,
    /// Serializes label-snapshot → session ingest → cache sweep. The
    /// session serializes its own ingest too; this lock pins the label
    /// snapshot to *this* ingest's pre-state.
    ingest_lock: Mutex<()>,
    /// Background pool finishing deadline-cut answers.
    completions: ThreadPool,
}

/// The multi-tenant serving front over a [`ShardedSession`].
///
/// `submit` is non-blocking: it either admits the request (returning a
/// [`TicketHandle`] the caller receives answers on) or rejects it with a
/// typed [`Rejected`]. All engine work happens on the batcher thread, the
/// shared `exec` pool underneath `query_many`, and the completion pool —
/// no async runtime.
pub struct ServeFront {
    core: Arc<Core>,
    tx: Mutex<Option<Sender<Ticket>>>,
    batcher: Mutex<Option<JoinHandle<()>>>,
}

impl ServeFront {
    pub fn new(session: Arc<ShardedSession>, cfg: ServeConfig) -> Self {
        let router = session.router();
        let shards = session.shard_count();
        let core = Arc::new(Core {
            session,
            router,
            admission: AdmissionController::new(cfg.quota_qps, cfg.quota_burst, cfg.queue_capacity),
            completions: ThreadPool::new(cfg.completion_workers.max(1)),
            cfg,
            cache: ResultCache::new(),
            metrics: ServeMetrics::default(),
            agg: Mutex::new(vec![ShardBatchStats::default(); shards]),
            ingest_lock: Mutex::new(()),
        });
        let (tx, rx) = channel::<Ticket>();
        let batcher_core = Arc::clone(&core);
        let batcher = std::thread::Builder::new()
            .name("serve-batcher".into())
            .spawn(move || run_batcher(batcher_core, rx))
            .expect("spawn serve batcher");
        Self {
            core,
            tx: Mutex::new(Some(tx)),
            batcher: Mutex::new(Some(batcher)),
        }
    }

    /// Submit one request for `tenant`: admitted (ticket handle) or a
    /// typed rejection, never a silent drop.
    pub fn submit(&self, tenant: &str, req: QueryRequest) -> Result<TicketHandle, Rejected> {
        let tx = self.tx.lock().expect("serve tx lock poisoned");
        let Some(tx) = tx.as_ref() else {
            return Err(Rejected::ShuttingDown);
        };
        if let Err(rej) = self.core.admission.try_admit(tenant) {
            match &rej {
                Rejected::Quota { .. } => self.core.metrics.rejected_quota.fetch_add(1, Relaxed),
                Rejected::QueueFull { .. } => {
                    self.core.metrics.rejected_queue.fetch_add(1, Relaxed)
                }
                Rejected::ShuttingDown => 0,
            };
            return Err(rej);
        }
        self.core.metrics.admitted.fetch_add(1, Relaxed);
        let (reply, rx) = channel();
        let ticket = Ticket { tenant: tenant.to_string(), req, reply };
        if tx.send(ticket).is_err() {
            self.core.admission.release();
            return Err(Rejected::ShuttingDown);
        }
        Ok(TicketHandle { rx })
    }

    /// Ingest through the front: snapshot the pre-ingest component labels
    /// of the batch endpoints, apply the batch to the session, then sweep
    /// exactly the dirty entries from the result cache (see `cache.rs`
    /// for why the pre-ingest labels cover every dirty component).
    pub fn ingest(&self, batch: &TripleBatch) -> Result<ShardedDeltaStats> {
        let _serial = self.core.ingest_lock.lock().expect("serve ingest lock poisoned");
        let mut items: FxHashSet<u64> = FxHashSet::default();
        for t in &batch.triples {
            items.insert(t.src.raw());
            items.insert(t.dst.raw());
        }
        let mut dirty: FxHashSet<u64> = FxHashSet::default();
        for s in self.core.session.shard_sessions() {
            let pre = s.pre();
            for &x in &items {
                if let Some(&l) = pre.cc_of.get(&x) {
                    dirty.insert(l);
                }
            }
        }
        let out = self.core.session.ingest(batch);
        // Sweep even when ingest errored: a faulted ingest can have
        // journaled some steps before failing, so affected entries (and
        // racing inserts, via the epoch bump) must still die.
        self.core.cache.invalidate(&dirty, &items);
        out
    }

    /// Drop every cached result (admin/benchmark hook). Bumps the cache
    /// epoch, so in-flight computations started before the clear cannot
    /// re-insert stale entries. Returns how many entries died.
    pub fn clear_cache(&self) -> usize {
        self.core.cache.clear()
    }

    /// Recover an interrupted ingest. The affected component set is
    /// unknown at this point, so the whole cache is dropped.
    pub fn recover(&self) -> Result<ShardedDeltaStats> {
        let _serial = self.core.ingest_lock.lock().expect("serve ingest lock poisoned");
        let out = self.core.session.recover();
        self.core.cache.clear();
        out
    }

    /// Block until every queued background completion has run (answers
    /// delivered, cacheable ones landed in the cache).
    pub fn wait_for_completions(&self) {
        self.core.completions.wait_idle();
    }

    /// The session underneath (read-only use by contract).
    pub fn session(&self) -> &Arc<ShardedSession> {
        &self.core.session
    }

    /// Requests admitted but not yet first-answered.
    pub fn in_flight(&self) -> usize {
        self.core.admission.in_flight()
    }

    /// Snapshot of every serving counter plus the accumulated per-shard
    /// batch stats.
    pub fn report(&self) -> ServeReport {
        let m = &self.core.metrics;
        let (cache_hits, cache_misses, cache_inserts, cache_stale_inserts, cache_invalidations) =
            self.core.cache.counters();
        ServeReport {
            admitted: m.admitted.load(Relaxed),
            rejected_quota: m.rejected_quota.load(Relaxed),
            rejected_queue: m.rejected_queue.load(Relaxed),
            windows: m.windows.load(Relaxed),
            coalesced: m.coalesced.load(Relaxed),
            deduped: m.deduped.load(Relaxed),
            partials_served: m.partials_served.load(Relaxed),
            completions: m.completions.load(Relaxed),
            cache_hits,
            cache_misses,
            cache_inserts,
            cache_stale_inserts,
            cache_invalidations,
            cache_entries: self.core.cache.len(),
            in_flight: self.core.admission.in_flight(),
            per_shard: self.core.agg.lock().expect("serve agg lock poisoned").clone(),
        }
    }

    /// Stop accepting requests, drain the queue, finish background
    /// completions. Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        let tx = self.tx.lock().expect("serve tx lock poisoned").take();
        drop(tx); // batcher's recv() errors out once the queue drains
        let handle = self.batcher.lock().expect("serve batcher lock poisoned").take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        self.core.completions.wait_idle();
    }
}

impl Drop for ServeFront {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Batcher loop: block for the first ticket, then collect until the
/// window closes, then process the window as one batch.
fn run_batcher(core: Arc<Core>, rx: Receiver<Ticket>) {
    loop {
        let first = match rx.recv() {
            Ok(t) => t,
            Err(_) => return, // front dropped its sender: drained, done
        };
        let mut window = vec![first];
        if !core.cfg.window.is_zero() && core.cfg.window_max > 1 {
            let closes = Instant::now() + core.cfg.window;
            while window.len() < core.cfg.window_max {
                let remaining = closes.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match rx.recv_timeout(remaining) {
                    Ok(t) => window.push(t),
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        core.process_window(window);
    }
}

impl Core {
    /// The item's current WCC label across shards, `None` if unknown.
    fn label_of(&self, item: u64) -> Option<u64> {
        for s in self.session.shard_sessions() {
            if let Some(&l) = s.pre().cc_of.get(&item) {
                return Some(l);
            }
        }
        None
    }

    /// Fold one scatter-gather report into the lifetime aggregate.
    fn merge_report(&self, report: &ShardedBatchReport) {
        let mut agg = self.agg.lock().expect("serve agg lock poisoned");
        if agg.len() < report.per_shard.len() {
            agg.resize_with(report.per_shard.len(), ShardBatchStats::default);
        }
        for (slot, s) in agg.iter_mut().zip(&report.per_shard) {
            slot.merge(s);
        }
    }

    /// Account one cache-served answer to the item's owning shard.
    fn absorb_one(&self, owner: usize, resp: &QueryResponse, outcome: QueryOutcome) {
        let mut agg = self.agg.lock().expect("serve agg lock poisoned");
        if agg.len() <= owner {
            agg.resize_with(owner + 1, ShardBatchStats::default);
        }
        agg[owner].absorb(resp, outcome);
    }

    /// First (and for most requests only) answer: releases the ticket's
    /// in-flight slot, then replies.
    fn deliver(
        &self,
        t: &Ticket,
        resp: QueryResponse,
        outcome: QueryOutcome,
        from_cache: bool,
        window_size: usize,
    ) {
        self.admission.release();
        let _ = t.reply.send(ServeResponse {
            tenant: t.tenant.clone(),
            response: resp,
            outcome,
            from_cache,
            window_size,
            completed: false,
        });
    }

    fn process_window(self: &Arc<Self>, window: Vec<Ticket>) {
        let n = window.len();
        self.metrics.windows.fetch_add(1, Relaxed);
        if n > 1 {
            self.metrics.coalesced.fetch_add(n as u64, Relaxed);
        }
        // Everything executed out of this window was computed at (or
        // after) this epoch; inserts are guarded on it.
        let epoch = self.cache.epoch();

        // 1) Cache hits answer without touching an engine.
        let mut pending: Vec<Ticket> = Vec::with_capacity(n);
        for t in window {
            if let Some(key) = CacheKey::of(self.router, &t.req) {
                if let Some((lineage, engine)) = self.cache.get(&key) {
                    let mut stats = QueryStats::new(engine);
                    stats.served_from_cache = true;
                    let resp = QueryResponse { lineage, stats };
                    let owner = self.session.shard_of(t.req.item).unwrap_or(0);
                    self.absorb_one(owner, &resp, QueryOutcome::Full);
                    self.deliver(&t, resp, QueryOutcome::Full, true, n);
                    continue;
                }
            }
            pending.push(t);
        }
        if pending.is_empty() {
            return;
        }

        // 2) Identical cacheable requests in one window execute once.
        let mut leaders: Vec<Ticket> = Vec::new();
        let mut followers: Vec<Vec<Ticket>> = Vec::new();
        let mut by_key: FxHashMap<CacheKey, usize> = FxHashMap::default();
        for t in pending {
            if let Some(key) = CacheKey::of(self.router, &t.req) {
                if let Some(&i) = by_key.get(&key) {
                    self.metrics.deduped.fetch_add(1, Relaxed);
                    followers[i].push(t);
                    continue;
                }
                by_key.insert(key, leaders.len());
            }
            leaders.push(t);
            followers.push(Vec::new());
        }

        // 3) One scatter-gather for the whole window. Per-item supervision
        // lives inside: a crashing request comes back `Failed` alone.
        let reqs: Vec<QueryRequest> = leaders.iter().map(|t| t.req.clone()).collect();
        let (resps, report) = self.session.query_many_report_on(self.router, &reqs);
        self.merge_report(&report);

        // 4) Cache, stream, and deliver.
        for (i, resp) in resps.into_iter().enumerate() {
            let t = &leaders[i];
            let outcome = report.outcomes[i];
            if outcome == QueryOutcome::Full {
                if let Some(key) = CacheKey::of(self.router, &t.req) {
                    let label = self.label_of(t.req.item);
                    self.cache.insert_if_epoch(
                        epoch,
                        key,
                        label,
                        resp.stats.engine,
                        resp.lineage.clone(),
                    );
                }
            }
            let deadline_cut = t.req.deadline.is_some()
                && outcome == QueryOutcome::Partial
                && !resp.stats.completeness.exhausted;
            if deadline_cut {
                self.metrics.partials_served.fetch_add(1, Relaxed);
                if self.cfg.complete_partials {
                    self.spawn_completion(t);
                }
            }
            for f in &followers[i] {
                self.deliver(f, resp.clone(), outcome, false, n);
            }
            self.deliver(t, resp, outcome, false, n);
        }
    }

    /// Finish a deadline-cut answer in the background: re-run without the
    /// deadline, cache a `Full` result (epoch-guarded), and stream the
    /// completed answer as the ticket's second response.
    fn spawn_completion(self: &Arc<Self>, t: &Ticket) {
        let core = Arc::clone(self);
        let mut full_req = t.req.clone();
        full_req.deadline = None;
        let reply = t.reply.clone();
        let tenant = t.tenant.clone();
        self.completions.submit(move || {
            let epoch = core.cache.epoch();
            // The supervised batch path again: a crash during completion
            // is a `Failed` second answer, not a dead worker thread.
            let (mut resps, report) =
                core.session.query_many_report_on(core.router, std::slice::from_ref(&full_req));
            core.merge_report(&report);
            let resp = resps.remove(0);
            let outcome = report.outcomes[0];
            if outcome == QueryOutcome::Full {
                if let Some(key) = CacheKey::of(core.router, &full_req) {
                    let label = core.label_of(full_req.item);
                    core.cache.insert_if_epoch(
                        epoch,
                        key,
                        label,
                        resp.stats.engine,
                        resp.lineage.clone(),
                    );
                }
            }
            core.metrics.completions.fetch_add(1, Relaxed);
            let _ = reply.send(ServeResponse {
                tenant,
                response: resp,
                outcome,
                from_cache: false,
                window_size: 1,
                completed: true,
            });
        });
    }
}
