//! `ShardedSession` — N independent [`ProvSession`] shards over the
//! component space, behind one scatter-gather front.
//!
//! A [`ShardPlan`](crate::provenance::shard::ShardPlan) assigns every
//! weakly connected component to one shard (lineages never cross
//! components, so shards never need each other); construction splits the
//! trace and preprocessed index with `split_by_plan` and opens one
//! [`ProvSession`] per shard on a **shared** minispark context — each shard
//! keeps its own `EngineSet` + epoch behind the existing
//! `RwLock<Arc<_>>` machinery, so all three engines and
//! [`EngineRouter::Auto`] work per shard unchanged.
//!
//! # Scatter-gather queries
//!
//! [`ShardedSession::query_many`] resolves each request's owning shard by
//! probing the per-shard epoch snapshots (a [`ShardRouter`] — one hash
//! lookup per shard, no front-side routing state to keep in sync), then
//! fans the whole batch across the shared `exec` worker pool,
//! order-preserving. Per-query [`QueryStats`] aggregate into a per-shard
//! [`ShardedBatchReport`]. The batch runs against one epoch snapshot *per
//! shard*; a concurrent ingest never splits a batch across index versions.
//!
//! # Sharded ingest and cross-shard merges
//!
//! [`ShardedSession::ingest`] routes a [`TripleBatch`]'s triples to only
//! the shards whose components they touch. The hard case is a batch edge
//! connecting components that live on *different* shards: the components
//! must merge, and a merged component must live on exactly one shard. The
//! resolver unions batch endpoints with the component labels they drag in
//! ([`UnionFind::groups`]), and for every group spanning >1 shard picks the
//! shard holding the most member nodes as the **winner** — mirroring
//! [`LabeledUnion`](crate::provenance::wcc::LabeledUnion)'s small-to-large
//! discipline, the smaller side moves. Losing shards have the migrating
//! components *extracted* (a `split_by_plan` with a keep-vs-migrate
//! assignment) and are rebuilt over their kept remainder
//! ([`ProvSession::replace_state`] — datasets have no removal path, so
//! shrinking is a rebuild of the smaller, losing side); the extracted
//! triples are prepended to the winner's sub-batch, whose own
//! [`ProvSession::ingest`] re-derives the merged component's structure
//! incrementally. The apply order is failure- and reader-safe: every
//! predictable error is preflighted before any shard mutates, and winners
//! absorb before losers shrink, so a concurrent query always finds the
//! migrating component on some shard. Equivalence with an unsharded
//! session — identical answers, CS membership and routing — is
//! property-tested in `rust/tests/sharded_props.rs`.
//!
//! # Crash-safe ingest: the migration journal
//!
//! The apply phase is **write-ahead journaled**: the full step plan (each
//! receiving shard's sub-batch ingest, each losing shard's rebuild) is
//! staged — and, with [`ShardedSession::with_journal_path`], durably
//! recorded — *before* the first shard mutates, and each step commits as
//! it lands. Steps are all-or-nothing at the [`ProvSession`] layer (a
//! failed `ingest`/`replace_state` discards its half-applied index and
//! leaves the served epoch untouched), so an injected fault or worker
//! crash mid-plan parks the remainder with its cursor;
//! [`ShardedSession::recover`] resumes from the first uncommitted step and
//! converges to exactly the state the uninterrupted ingest would have
//! produced — property-tested by interrupting a forced cross-shard merge
//! at *every* step index (`rust/tests/sharded_props.rs`).
//!
//! [`QueryStats`]: crate::provenance::query::QueryStats

use super::engines::EngineSet;
use super::session::{execute_supervised, EngineRouter, ProvSession};
use crate::config::EngineConfig;
use crate::exec::par_map_indexed;
use crate::fault::FaultSite;
use crate::minispark::MiniSpark;
use crate::provenance::incremental::{DeltaStats, TripleBatch};
use crate::provenance::journal::MigrationJournal;
use crate::provenance::model::{ProvTriple, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::{ProvenanceEngine, QueryOutcome, QueryRequest, QueryResponse};
use crate::provenance::shard::{merge_shards, ShardAssignment, ShardPlan};
use crate::provenance::wcc::UnionFind;
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::SplitSet;
use anyhow::{ensure, Result};
use rustc_hash::{FxHashMap, FxHashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Resolves items to owning shards against a fixed set of per-shard
/// preprocessed snapshots: probe each shard's `cc_of` (one hash lookup per
/// shard); unknown items fall back to the plan's deterministic hash — every
/// shard answers an unknown item identically (empty lineage via CSProv's
/// index miss), so any deterministic choice preserves equivalence. Routing
/// needs only the data, so it never builds a lazily opened shard's engines.
pub struct ShardRouter<'a> {
    plan: &'a ShardPlan,
    pres: &'a [Arc<Preprocessed>],
}

impl<'a> ShardRouter<'a> {
    pub fn new(plan: &'a ShardPlan, pres: &'a [Arc<Preprocessed>]) -> Self {
        Self { plan, pres }
    }

    /// Shard that answers queries for `item`.
    pub fn owner(&self, item: u64) -> usize {
        self.known_owner(item).unwrap_or_else(|| self.plan.shard_of_item(item))
    }

    /// Shard whose component space contains `item`, if any.
    pub fn known_owner(&self, item: u64) -> Option<usize> {
        self.pres.iter().position(|p| p.cc_of.contains_key(&item))
    }
}

/// Per-shard aggregate of the [`QueryStats`] a scattered batch produced on
/// that shard.
///
/// [`QueryStats`]: crate::provenance::query::QueryStats
#[derive(Debug, Clone, Default)]
pub struct ShardBatchStats {
    pub requests: usize,
    pub partitions_scanned: u64,
    pub rows_examined: u64,
    pub rows_shuffled: u64,
    pub rows_collected: u64,
    /// Fused lazy-planner stages the shard's engines ran (or replayed
    /// from a hot-component memo) for this batch.
    pub stages_run: u64,
    /// Logical ops folded into those stages.
    pub ops_fused: u64,
    /// Intermediate rows stage fusion never materialized on this shard.
    pub intermediates_avoided: u64,
    /// Requests answered completely ([`QueryOutcome::Full`]).
    pub full: usize,
    /// Degraded answers — cap- or deadline-bounded ([`QueryOutcome::Partial`]).
    pub partial: usize,
    /// Requests whose every supervised attempt died ([`QueryOutcome::Failed`]).
    pub failed: usize,
    /// Requests answered from the serving front's result cache without
    /// touching an engine (their scan counters are all zero).
    pub from_cache: usize,
    /// Sum of the per-query phase wall times attributed to this shard.
    pub wall: Duration,
}

impl ShardBatchStats {
    /// Fold one response into the aggregate. Public so layers above the
    /// sharded scatter (the serving front) can account answers they
    /// produced without an engine call — e.g. cache hits — in the same
    /// shape.
    pub fn absorb(&mut self, resp: &QueryResponse, outcome: QueryOutcome) {
        self.requests += 1;
        self.partitions_scanned += resp.stats.partitions_scanned;
        self.rows_examined += resp.stats.rows_examined;
        self.rows_shuffled += resp.stats.rows_shuffled;
        self.rows_collected += resp.stats.rows_collected;
        self.stages_run += resp.stats.stages_run;
        self.ops_fused += resp.stats.ops_fused;
        self.intermediates_avoided += resp.stats.intermediates_avoided;
        match outcome {
            QueryOutcome::Full => self.full += 1,
            QueryOutcome::Partial => self.partial += 1,
            QueryOutcome::Failed => self.failed += 1,
        }
        if resp.stats.served_from_cache {
            self.from_cache += 1;
        }
        self.wall += resp.stats.total_time();
    }

    /// Fold another aggregate into this one (field-wise sum).
    pub fn merge(&mut self, other: &ShardBatchStats) {
        self.requests += other.requests;
        self.partitions_scanned += other.partitions_scanned;
        self.rows_examined += other.rows_examined;
        self.rows_shuffled += other.rows_shuffled;
        self.rows_collected += other.rows_collected;
        self.stages_run += other.stages_run;
        self.ops_fused += other.ops_fused;
        self.intermediates_avoided += other.intermediates_avoided;
        self.full += other.full;
        self.partial += other.partial;
        self.failed += other.failed;
        self.from_cache += other.from_cache;
        self.wall += other.wall;
    }

    /// One-line rendering for aggregate rows.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs ({} full, {} partial, {} failed, {} from cache), \
             {} parts scanned, {} rows examined",
            self.requests,
            self.full,
            self.partial,
            self.failed,
            self.from_cache,
            self.partitions_scanned,
            self.rows_examined,
        )
    }
}

/// The batch-level report of one scattered [`ShardedSession::query_many`]:
/// per-shard request counts and scan volumes, plus totals.
#[derive(Debug, Clone, Default)]
pub struct ShardedBatchReport {
    /// Indexed by shard.
    pub per_shard: Vec<ShardBatchStats>,
    /// Per-request classification, in request order: a failing shard (or a
    /// deadline cut) degrades its own items to `Partial`/`Failed` while the
    /// rest of the batch answers `Full` — failures are isolated per item,
    /// never batch-fatal.
    pub outcomes: Vec<QueryOutcome>,
}

impl ShardedBatchReport {
    /// Aggregate over all shards.
    pub fn total(&self) -> ShardBatchStats {
        let mut t = ShardBatchStats::default();
        for s in &self.per_shard {
            t.merge(s);
        }
        t
    }

    /// Multi-line rendering (one line per shard that served requests).
    pub fn summary(&self) -> String {
        use crate::util::fmt::{human_count, human_duration};
        let mut out = String::new();
        for (i, s) in self.per_shard.iter().enumerate() {
            if s.requests == 0 {
                continue;
            }
            out.push_str(&format!(
                "shard {i}: {} reqs, {} parts scanned, {} rows examined, {} collected, {}\n",
                s.requests,
                s.partitions_scanned,
                human_count(s.rows_examined),
                human_count(s.rows_collected),
                human_duration(s.wall),
            ));
        }
        let t = self.total();
        out.push_str(&format!(
            "total: {} reqs, {} parts scanned, {} rows examined across {} shards\n",
            t.requests,
            t.partitions_scanned,
            human_count(t.rows_examined),
            self.per_shard.len(),
        ));
        if t.partial > 0 || t.failed > 0 {
            out.push_str(&format!(
                "outcomes: {} full, {} partial, {} failed\n",
                t.full, t.partial, t.failed,
            ));
        }
        out
    }
}

/// What one [`ShardedSession::ingest`] did: the per-shard deltas plus the
/// cross-shard merge/migration work the front performed.
#[derive(Debug, Clone, Default)]
pub struct ShardedDeltaStats {
    /// Sharded batches applied since the session opened.
    pub batch: u64,
    pub new_triples: usize,
    /// Merge groups whose components spanned more than one shard.
    pub cross_shard_merges: usize,
    /// Components moved off a losing shard.
    pub migrated_components: usize,
    /// Triples moved with them (re-ingested on the winning shard).
    pub migrated_triples: usize,
    /// Losing shards rebuilt over their kept remainder by this batch's
    /// migrations (a shard can be rebuilt even when it ingested no rows —
    /// its `per_shard` entry is `None` in that case).
    pub rebuilt_shards: Vec<usize>,
    /// Per-shard delta stats (`None` = no sub-batch was ingested on the
    /// shard; see [`rebuilt_shards`](Self::rebuilt_shards) for shards that
    /// were still modified by a migration).
    pub per_shard: Vec<Option<DeltaStats>>,
    /// Steps in this batch's write-ahead migration journal (every
    /// shard-mutating action is one journaled, individually recoverable
    /// step).
    pub journal_steps: usize,
}

impl ShardedDeltaStats {
    /// One-line rendering for CLI / bench output.
    pub fn summary(&self) -> String {
        let touched = self.per_shard.iter().filter(|d| d.is_some()).count();
        format!(
            "batch={} new_triples={} shards_ingesting={}/{} cross_shard_merges={} \
             migrated_components={} migrated_triples={} rebuilt_shards={:?} \
             journal_steps={}",
            self.batch,
            self.new_triples,
            touched,
            self.per_shard.len(),
            self.cross_shard_merges,
            self.migrated_components,
            self.migrated_triples,
            self.rebuilt_shards,
            self.journal_steps,
        )
    }
}

/// One shard-mutating action of a sharded ingest, staged before any shard
/// changes. Steps hold their full inputs (`TripleBatch` / kept state), so
/// an interrupted plan can resume without re-deriving anything — and since
/// each [`ProvSession`] mutation is all-or-nothing, re-running the step at
/// the journal cursor is always safe.
enum PlannedStep {
    /// Apply a sub-batch through the shard's incremental ingest path.
    Ingest { shard: usize, batch: TripleBatch },
    /// Rebuild a losing shard over its kept remainder.
    Replace { shard: usize, trace: Arc<Trace>, pre: Arc<Preprocessed> },
}

impl PlannedStep {
    fn describe(&self) -> String {
        match self {
            PlannedStep::Ingest { shard, batch } => {
                format!("ingest shard {shard} ({} triples)", batch.len())
            }
            PlannedStep::Replace { shard, trace, .. } => {
                format!("replace shard {shard} ({} kept triples)", trace.len())
            }
        }
    }
}

/// An interrupted sharded ingest, parked for [`ShardedSession::recover`]:
/// the journal (cursor at the first uncommitted step), the staged steps,
/// and the stats accumulated by the steps that already landed.
struct PendingMigration {
    journal: MigrationJournal,
    steps: Vec<PlannedStep>,
    stats: ShardedDeltaStats,
}

/// A sharded query session: the same query surface as [`ProvSession`]
/// (route / execute / `query_many` / ingest), served by N component-space
/// shards behind a scatter-gather front.
///
/// ```
/// use provspark::config::EngineConfig;
/// use provspark::harness::{EngineRouter, ProvSession, ShardedSession};
/// use provspark::provenance::pipeline::{preprocess, WccImpl};
/// use provspark::provenance::query::QueryRequest;
/// use provspark::workflow::generator::{generate, GeneratorConfig};
/// use std::sync::Arc;
///
/// let (trace, graph, splits) =
///     generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
/// let pre = preprocess(&trace, &graph, &splits, 100, 50, WccImpl::Driver);
/// let mut cfg = EngineConfig::default();
/// cfg.cluster.job_overhead_us = 0;
/// let (trace, pre) = (Arc::new(trace), Arc::new(pre));
///
/// let single = ProvSession::new(&cfg, Arc::clone(&trace), Arc::clone(&pre)).unwrap();
/// let sharded = ShardedSession::new(&cfg, trace, pre, 4).unwrap();
/// assert_eq!(sharded.shard_count(), 4);
///
/// // Sharding is invisible to queries: identical answers and routing.
/// let item = single.trace().triples[0].dst.raw();
/// let req = QueryRequest::new(item);
/// let (a, b) = (single.execute_on(EngineRouter::Auto, &req),
///               sharded.execute_on(EngineRouter::Auto, &req));
/// assert_eq!(a.lineage, b.lineage);
/// assert_eq!(a.stats.engine, b.stats.engine);
/// ```
pub struct ShardedSession {
    sc: MiniSpark,
    plan: ShardPlan,
    router: EngineRouter,
    shards: Vec<ProvSession>,
    /// Sharded batches applied (the front's own epoch counter — shard
    /// epochs advance independently, only when a batch touches them).
    batches: AtomicU64,
    /// Serializes sharded ingestion (migrations touch multiple shards).
    ingest_lock: Mutex<()>,
    /// An interrupted ingest's parked plan (see [`recover`](Self::recover)).
    pending: Mutex<Option<PendingMigration>>,
    /// Where the write-ahead migration journal is mirrored on disk, if
    /// anywhere ([`with_journal_path`](Self::with_journal_path)).
    journal_path: Option<PathBuf>,
}

impl ShardedSession {
    /// Split `trace`/`pre` across `shards` component-space shards and open
    /// one session per shard on a fresh shared minispark context.
    pub fn new(
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
        shards: usize,
    ) -> Result<Self> {
        let sc = MiniSpark::new(cfg.cluster.clone());
        Self::with_context(&sc, cfg, trace, pre, shards)
    }

    /// [`new`](Self::new) on an existing context (shares its worker pool).
    pub fn with_context(
        sc: &MiniSpark,
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
        shards: usize,
    ) -> Result<Self> {
        ensure!(shards >= 1, "shard count must be >= 1");
        let plan = ShardPlan::new(shards);
        let asg = plan.assignment(&pre.cc_of);
        let traces = trace.split_by_plan(&pre.cc_of, &asg)?;
        let pres = pre.split_by_plan(&asg)?;
        // Shards open lazily: each builds its engines (and, under a memory
        // budget, spills its datasets) only when first queried or ingested
        // into, so a wide front pays construction for hot shards only.
        let mut sessions = Vec::with_capacity(shards);
        for (t, p) in traces.into_iter().zip(pres) {
            sessions.push(ProvSession::with_context_lazy(sc, cfg, Arc::new(t), Arc::new(p)));
        }
        Ok(Self {
            sc: sc.clone(),
            plan,
            router: EngineRouter::Auto,
            shards: sessions,
            batches: AtomicU64::new(0),
            ingest_lock: Mutex::new(()),
            pending: Mutex::new(None),
            journal_path: None,
        })
    }

    /// Set the default routing policy (builder-style).
    pub fn with_router(mut self, router: EngineRouter) -> Self {
        self.router = router;
        self
    }

    /// Mirror every ingest's write-ahead migration journal to a file
    /// (builder-style). A file left behind after a process crash is the
    /// durable evidence that a batch never fully applied — the CLI reports
    /// it on startup and treats the stored (pre-batch) state as canonical.
    pub fn with_journal_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.journal_path = Some(path.into());
        self
    }

    /// Set the workflow every shard re-partitions dirty components against
    /// on ingest (builder-style; see [`ProvSession::with_workflow`]).
    pub fn with_workflow(mut self, graph: DependencyGraph, splits: SplitSet) -> Self {
        self.shards = self
            .shards
            .into_iter()
            .map(|s| s.with_workflow(graph.clone(), splits.clone()))
            .collect();
        self
    }

    pub fn router(&self) -> EngineRouter {
        self.router
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard sessions (shard `i` serves plan bucket `i`).
    ///
    /// **Read-only by contract**: querying through a shard directly is
    /// fine, but never call [`ProvSession::ingest`] (or
    /// [`ProvSession::replace_state`]) on one — a batch referencing a node
    /// owned by another shard would be treated as brand-new there, putting
    /// the node on two shards and breaking the one-shard-per-component
    /// invariant every front operation relies on. All ingestion must go
    /// through [`ShardedSession::ingest`], which resolves cross-shard
    /// merges first.
    pub fn shard_sessions(&self) -> &[ProvSession] {
        &self.shards
    }

    pub fn context(&self) -> &MiniSpark {
        &self.sc
    }

    /// Sharded batches ingested through this front.
    pub fn batches_ingested(&self) -> u64 {
        self.batches.load(Ordering::SeqCst)
    }

    /// Shard whose component space currently contains `item` (`None` for
    /// unknown items, which any shard rejects identically). Never builds a
    /// lazy shard's engines.
    pub fn shard_of(&self, item: u64) -> Option<usize> {
        let pres = self.pre_snapshot();
        ShardRouter::new(&self.plan, &pres).known_owner(item)
    }

    /// Name of the engine a routing policy resolves to for one item on its
    /// owning shard (same contract as [`ProvSession::route`]).
    pub fn route(&self, router: EngineRouter, item: u64) -> &'static str {
        let pres = self.pre_snapshot();
        let owner = ShardRouter::new(&self.plan, &pres).owner(item);
        self.shards[owner].route(router, item)
    }

    /// Answer one request with the session's default router.
    pub fn execute(&self, req: &QueryRequest) -> QueryResponse {
        self.execute_on(self.router, req)
    }

    /// Answer one request with an explicit routing policy on the owning
    /// shard (building that shard's engines if it was still lazy).
    pub fn execute_on(&self, router: EngineRouter, req: &QueryRequest) -> QueryResponse {
        let pres = self.pre_snapshot();
        let owner = ShardRouter::new(&self.plan, &pres).owner(req.item);
        self.shards[owner].execute_on(router, req)
    }

    /// Scatter a batch across the shards and gather the responses in
    /// request order (see [`query_many_report`](Self::query_many_report)
    /// for the per-shard cost report).
    pub fn query_many(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        self.query_many_on(self.router, reqs)
    }

    /// [`query_many`](Self::query_many) with an explicit routing policy.
    pub fn query_many_on(
        &self,
        router: EngineRouter,
        reqs: &[QueryRequest],
    ) -> Vec<QueryResponse> {
        self.query_many_report_on(router, reqs).0
    }

    /// Scatter-gather with the batch-level report: each request is resolved
    /// to its owning shard (one epoch snapshot per shard for the whole
    /// batch), the full batch fans out across the shared `exec` worker
    /// pool, responses come back in request order, and every shard's
    /// per-query stats aggregate into a [`ShardedBatchReport`].
    pub fn query_many_report(
        &self,
        reqs: &[QueryRequest],
    ) -> (Vec<QueryResponse>, ShardedBatchReport) {
        self.query_many_report_on(self.router, reqs)
    }

    /// [`query_many_report`](Self::query_many_report) with an explicit
    /// routing policy.
    pub fn query_many_report_on(
        &self,
        router: EngineRouter,
        reqs: &[QueryRequest],
    ) -> (Vec<QueryResponse>, ShardedBatchReport) {
        let pres = self.pre_snapshot();
        let front = ShardRouter::new(&self.plan, &pres);
        let owners: Vec<usize> = reqs.iter().map(|r| front.owner(r.item)).collect();
        // Snapshot — and lazily build — only the shards this batch
        // touches; the whole batch runs against one epoch per shard.
        let mut epochs: Vec<Option<Arc<EngineSet>>> = vec![None; self.shards.len()];
        for &o in &owners {
            if epochs[o].is_none() {
                epochs[o] = Some(self.shards[o].engines());
            }
        }
        let parallelism = self.sc.config().executors.max(1);
        // Supervised per item: a crash on one shard's engine yields a
        // `Failed` outcome for that item alone; the rest of the batch is
        // unaffected.
        let answered = par_map_indexed(reqs, parallelism, |i, req| {
            let epoch = epochs[owners[i]].as_ref().expect("owner snapshotted above");
            execute_supervised(epoch.route(router, req.item), req)
        });
        let mut report = ShardedBatchReport {
            per_shard: vec![ShardBatchStats::default(); self.shards.len()],
            outcomes: Vec::with_capacity(answered.len()),
        };
        let mut responses = Vec::with_capacity(answered.len());
        for (owner, (resp, outcome)) in owners.iter().zip(answered) {
            report.per_shard[*owner].absorb(&resp, outcome);
            report.outcomes.push(outcome);
            responses.push(resp);
        }
        (responses, report)
    }

    /// Ingest a batch through the sharded front: triples are routed to only
    /// the shards whose components they touch; components merged *across*
    /// shards by batch edges are migrated to the winning (larger) shard,
    /// and every receiving shard absorbs its sub-batch through the normal
    /// [`ProvSession::ingest`] incremental path. All predictable failures
    /// are preflighted before any shard mutates; winners absorb before
    /// losers shrink, so queries running concurrently always find every
    /// component on some shard (each serving a legitimate epoch).
    pub fn ingest(&self, batch: &TripleBatch) -> Result<ShardedDeltaStats> {
        let _serial = self.ingest_lock.lock().expect("sharded ingest lock poisoned");
        let n = self.shards.len();
        let mut stats = ShardedDeltaStats {
            new_triples: batch.len(),
            per_shard: vec![None; n],
            ..Default::default()
        };
        if batch.is_empty() {
            stats.batch = self.batches.fetch_add(1, Ordering::SeqCst) + 1;
            return Ok(stats);
        }
        // Per-shard data snapshots: routing, sizing and extraction only
        // need trace + pre, so a shard that neither receives rows nor
        // loses a component never builds its engines.
        let datas: Vec<(Arc<Trace>, Arc<Preprocessed>)> =
            self.shards.iter().map(|s| (s.trace(), s.pre())).collect();

        // ---- Resolve merge groups --------------------------------------
        // Union batch endpoints with the component labels they drag in: a
        // label is itself a member node of its component, so two batch
        // groups touching the same component share a union-find root, and
        // a group's members name every existing component it merges.
        let mut uf = UnionFind::new();
        let mut known: FxHashMap<u64, (usize, u64)> = FxHashMap::default();
        for t in &batch.triples {
            let (s, d) = (t.src.raw(), t.dst.raw());
            uf.union(s, d);
            for x in [s, d] {
                if known.contains_key(&x) {
                    continue;
                }
                for (si, (_, p)) in datas.iter().enumerate() {
                    if let Some(&l) = p.cc_of.get(&x) {
                        known.insert(x, (si, l));
                        known.entry(l).or_insert((si, l));
                        uf.union(x, l);
                        break;
                    }
                }
            }
        }

        struct GroupInfo {
            min_member: u64,
            /// shard → labels of its components this group merges.
            involved: FxHashMap<usize, FxHashSet<u64>>,
        }
        let groups = uf.groups();
        let mut infos: Vec<(u64, GroupInfo)> = Vec::with_capacity(groups.len());
        // Component sizes are only needed for contested (multi-shard)
        // groups; collect those labels per shard so each shard's node map
        // is scanned at most once.
        let mut need: FxHashMap<usize, FxHashSet<u64>> = FxHashMap::default();
        for (&root, members) in &groups {
            let mut gi = GroupInfo { min_member: u64::MAX, involved: FxHashMap::default() };
            for m in members {
                gi.min_member = gi.min_member.min(*m);
                if let Some(&(s, l)) = known.get(m) {
                    gi.involved.entry(s).or_default().insert(l);
                }
            }
            if gi.involved.len() > 1 {
                for (&s, ls) in &gi.involved {
                    need.entry(s).or_default().extend(ls.iter().copied());
                }
            }
            infos.push((root, gi));
        }
        let mut size_of: FxHashMap<(usize, u64), usize> = FxHashMap::default();
        for (&s, labels) in &need {
            for l in datas[s].1.cc_of.values() {
                if labels.contains(l) {
                    *size_of.entry((s, *l)).or_insert(0) += 1;
                }
            }
        }

        // ---- Pick winners, schedule migrations -------------------------
        let mut target_of: FxHashMap<u64, usize> = FxHashMap::default();
        let mut migrate: FxHashMap<usize, FxHashMap<u64, usize>> = FxHashMap::default();
        for (root, gi) in &infos {
            let target = match gi.involved.len() {
                // All-new component: hash its minimum node id — the
                // canonical label it will have.
                0 => self.plan.shard_of_item(gi.min_member),
                1 => *gi.involved.keys().next().expect("one involved shard"),
                _ => {
                    stats.cross_shard_merges += 1;
                    // Winner = shard with the most member nodes across its
                    // involved components (the smaller side moves); ties
                    // break to the lowest shard index for determinism.
                    let mut by_size: Vec<(usize, usize)> = gi
                        .involved
                        .iter()
                        .map(|(&s, ls)| {
                            let sz: usize = ls
                                .iter()
                                .map(|l| size_of.get(&(s, *l)).copied().unwrap_or(0))
                                .sum();
                            (s, sz)
                        })
                        .collect();
                    by_size.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                    let winner = by_size[0].0;
                    for (&s, ls) in &gi.involved {
                        if s == winner {
                            continue;
                        }
                        for &l in ls {
                            migrate.entry(s).or_default().insert(l, winner);
                            stats.migrated_components += 1;
                        }
                    }
                    winner
                }
            };
            target_of.insert(*root, target);
        }

        // ---- Route batch triples to their target shards -----------------
        let mut subs: Vec<Vec<ProvTriple>> = vec![Vec::new(); n];
        for t in &batch.triples {
            let root = uf.find(t.src.raw());
            subs[target_of[&root]].push(*t);
        }

        // ---- Extract migrating components from losing shards ------------
        // Bucket 0 = keep; buckets 1.. = one per distinct winning shard.
        // Extraction only *reads* the epoch snapshots: the kept remainder
        // and the extracted raw triples are staged here, and no shard state
        // is mutated until the preflight below has passed.
        let mut extra: Vec<Vec<ProvTriple>> = vec![Vec::new(); n];
        let mut kept: Vec<Option<(Trace, Preprocessed)>> = (0..n).map(|_| None).collect();
        let mut losers: Vec<usize> = migrate.keys().copied().collect();
        losers.sort_unstable();
        for &s in &losers {
            let moving = &migrate[&s];
            let mut winners: Vec<usize> =
                moving.values().copied().collect::<FxHashSet<usize>>().into_iter().collect();
            winners.sort_unstable();
            let bucket_of: FxHashMap<usize, usize> =
                winners.iter().enumerate().map(|(i, &w)| (w, i + 1)).collect();
            let (shard_trace, shard_pre) = &datas[s];
            let mut of_label: FxHashMap<u64, usize> = FxHashMap::default();
            for &l in shard_pre.cc_of.values() {
                of_label
                    .entry(l)
                    .or_insert_with(|| moving.get(&l).map(|w| bucket_of[w]).unwrap_or(0));
            }
            let asg = ShardAssignment::new(1 + winners.len(), of_label);
            let mut parts_t = shard_trace.split_by_plan(&shard_pre.cc_of, &asg)?;
            let parts_p = shard_pre.split_by_plan(&asg)?;
            let kept_t = parts_t.remove(0);
            let mut kept_p = parts_p.into_iter().next().expect("keep bucket");
            // The keep bucket stays at this shard's position in the
            // *session's* plan — not position 0 of the extraction split.
            kept_p.shard_index = shard_pre.shard_index;
            kept_p.shard_count = shard_pre.shard_count;
            kept[s] = Some((kept_t, kept_p));
            for (bi, &w) in winners.iter().enumerate() {
                stats.migrated_triples += parts_t[bi].len();
                extra[w].extend_from_slice(&parts_t[bi].triples);
            }
        }

        // ---- Preflight: fail before mutating anything -------------------
        // Every predictable per-shard ingest failure (θ unrecorded,
        // mismatched workflow fingerprint, triple-index overflow) must
        // surface *before* any shard state changes — an error after a
        // partial apply would strand migrated components between shards.
        // The triple-index bound is per shard — the whole point of
        // sharding is that only each shard's own index must fit.
        for s in 0..n {
            if extra[s].is_empty() && subs[s].is_empty() {
                continue;
            }
            let after = datas[s].0.len() + extra[s].len() + subs[s].len();
            ensure!(
                after <= u32::MAX as usize,
                "shard {s} would exceed the u32 triple index ({after} rows)"
            );
            let pre = &datas[s].1;
            ensure!(
                pre.theta != 0,
                "shard {s} has θ = 0 (pre-epoch index): re-run preprocess with θ ≥ 1 \
                 before ingesting"
            );
            let fp = self.shards[s].workflow_fingerprint();
            ensure!(
                pre.workflow_fingerprint == 0 || pre.workflow_fingerprint == fp,
                "shard {s} was preprocessed under a different workflow (recorded \
                 fingerprint {:#018x}, session workflow {:#018x})",
                pre.workflow_fingerprint,
                fp,
            );
        }

        // ---- Stage the journaled apply plan -----------------------------
        // Winners absorb first, losers shrink last: until a loser's
        // `replace_state` lands, its previous epoch still serves the
        // migrating component — so a concurrent query always finds the
        // component on *some* shard (the loser's pre-merge state or the
        // winner's merged state, each a legitimate epoch), never a silent
        // empty answer. Every step carries its full inputs, so the plan is
        // resumable from any cursor.
        let mut steps: Vec<PlannedStep> = Vec::new();
        for s in 0..n {
            if kept[s].is_some() || (extra[s].is_empty() && subs[s].is_empty()) {
                continue;
            }
            let mut triples = std::mem::take(&mut extra[s]);
            triples.append(&mut subs[s]);
            steps.push(PlannedStep::Ingest { shard: s, batch: TripleBatch::new(triples) });
        }
        for &s in &losers {
            let (kept_t, kept_p) = kept[s].take().expect("loser kept state staged above");
            steps.push(PlannedStep::Replace {
                shard: s,
                trace: Arc::new(kept_t),
                pre: Arc::new(kept_p),
            });
            // A loser can also be receiving rows (for other merge groups,
            // or as another group's winner): its sub-batch applies to the
            // kept state it was staged against.
            if !(extra[s].is_empty() && subs[s].is_empty()) {
                let mut triples = std::mem::take(&mut extra[s]);
                triples.append(&mut subs[s]);
                steps.push(PlannedStep::Ingest { shard: s, batch: TripleBatch::new(triples) });
            }
        }
        stats.journal_steps = steps.len();

        // ---- Journal the plan, then execute it --------------------------
        // The journal (durably, when a path is configured) records every
        // step before the first shard mutates.
        let descriptions: Vec<String> = steps.iter().map(PlannedStep::describe).collect();
        let journal = MigrationJournal::begin(descriptions, self.journal_path.as_deref())?;
        self.run_steps(PendingMigration { journal, steps, stats })
    }

    /// Execute a staged migration plan. On a step failure the remaining
    /// plan is parked (with its journal) for [`recover`](Self::recover);
    /// completed steps stay committed — each is all-or-nothing at the
    /// shard-session layer, so the observable state is always "plan applied
    /// up to the cursor".
    fn run_steps(&self, p: PendingMigration) -> Result<ShardedDeltaStats> {
        // A fresh plan of only Ingest steps touches each shard at most
        // once and its steps are independent, so they fan across the
        // worker pool. Plans with Replace steps (cross-shard migrations)
        // keep the sequential path: their winner-before-loser ordering is
        // what keeps concurrent queries correct.
        let pure_ingest = p.journal.cursor() == 0
            && p.steps.len() > 1
            && p.steps.iter().all(|s| matches!(s, PlannedStep::Ingest { .. }));
        if pure_ingest {
            self.run_steps_parallel(p)
        } else {
            self.run_steps_sequential(p)
        }
    }

    /// The one-step-at-a-time plan executor, resumable from any journal
    /// cursor.
    fn run_steps_sequential(&self, mut p: PendingMigration) -> Result<ShardedDeltaStats> {
        while !p.journal.is_complete() {
            let i = p.journal.cursor();
            // The per-step fault probe (FaultSite::Journal): the injection
            // point the recovery property test drives to interrupt a plan
            // at every step index.
            let probed: Result<()> = match self.sc.fault() {
                Some(inj) => inj.fire_io(FaultSite::Journal),
                None => Ok(()),
            };
            let effect = probed.and_then(|()| match &p.steps[i] {
                PlannedStep::Ingest { shard, batch } => {
                    self.shards[*shard].ingest(batch).map(|d| (*shard, Some(d)))
                }
                PlannedStep::Replace { shard, trace, pre } => self.shards[*shard]
                    .replace_state(Arc::clone(trace), Arc::clone(pre))
                    .map(|()| (*shard, None)),
            });
            let committed = match effect {
                Ok((s, Some(delta))) => {
                    p.stats.per_shard[s] = Some(delta);
                    p.journal.mark_done()
                }
                Ok((s, None)) => {
                    p.stats.rebuilt_shards.push(s);
                    p.journal.mark_done()
                }
                Err(e) => Err(e),
            };
            if let Err(e) = committed {
                let desc = p.steps[i].describe();
                let total = p.steps.len();
                *self.pending.lock().expect("pending migration lock poisoned") = Some(p);
                return Err(e.context(format!(
                    "sharded ingest interrupted at journal step {i}/{total} ({desc}); \
                     every committed step landed atomically and shard state is \
                     consistent — call recover() to resume"
                )));
            }
        }
        self.retire(p)
    }

    /// Execute a pure-ingest plan concurrently: the per-step journal fault
    /// probes are drawn sequentially up front (so an `io:journal:@k` plan
    /// targets the same step it would sequentially), then every un-faulted
    /// step runs in parallel — each shard's ingest is independent and
    /// all-or-nothing. Failed steps are re-journaled as a fresh remainder
    /// plan and parked for [`recover`](Self::recover); completed steps are
    /// committed in their shards, so the remainder journal is exactly the
    /// uncommitted set.
    fn run_steps_parallel(&self, mut p: PendingMigration) -> Result<ShardedDeltaStats> {
        let probe_errs: Vec<Option<String>> = p
            .steps
            .iter()
            .map(|_| match self.sc.fault() {
                Some(inj) => inj.fire_io(FaultSite::Journal).err().map(|e| format!("{e:#}")),
                None => None,
            })
            .collect();
        let parallelism = self.sc.config().executors.max(1);
        let results: Vec<Result<(usize, DeltaStats)>> =
            par_map_indexed(&p.steps, parallelism, |i, step| {
                if let Some(msg) = &probe_errs[i] {
                    anyhow::bail!("{msg}");
                }
                let PlannedStep::Ingest { shard, batch } = step else {
                    unreachable!("pure-ingest plan holds only Ingest steps")
                };
                self.shards[*shard].ingest(batch).map(|d| (*shard, d))
            });
        let mut failed: Vec<(usize, String)> = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Ok((s, d)) => p.stats.per_shard[s] = Some(d),
                Err(e) => failed.push((i, format!("{e:#}"))),
            }
        }
        if failed.is_empty() {
            while !p.journal.is_complete() {
                if let Err(e) = p.journal.mark_done() {
                    // The step landed and the cursor advanced; a failed
                    // durable append only under-counts the journal file
                    // (see `MigrationJournal::mark_done`).
                    eprintln!("provspark: warning: journal commit append failed: {e:#}");
                }
            }
            return self.retire(p);
        }
        let total = p.steps.len();
        let keep: FxHashSet<usize> = failed.iter().map(|&(i, _)| i).collect();
        let steps: Vec<PlannedStep> = p
            .steps
            .into_iter()
            .enumerate()
            .filter_map(|(i, s)| keep.contains(&i).then_some(s))
            .collect();
        let descriptions: Vec<String> = steps.iter().map(PlannedStep::describe).collect();
        let path = self.journal_path.as_deref();
        let journal = match MigrationJournal::begin(descriptions.clone(), path) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("provspark: warning: remainder journal not durably recorded: {e:#}");
                MigrationJournal::begin(descriptions, None)
                    .expect("memory-only journal cannot fail")
            }
        };
        let (first_i, first_msg) = &failed[0];
        let n_failed = failed.len();
        let msg = format!(
            "sharded ingest: {n_failed}/{total} parallel ingest step(s) failed (first: \
             step {first_i}: {first_msg}); every completed step landed atomically and \
             shard state is consistent — call recover() to resume"
        );
        *self.pending.lock().expect("pending migration lock poisoned") =
            Some(PendingMigration { journal, steps, stats: p.stats });
        anyhow::bail!("{msg}")
    }

    /// All steps committed: retire the journal and stamp the batch number.
    fn retire(&self, p: PendingMigration) -> Result<ShardedDeltaStats> {
        let PendingMigration { journal, stats: mut done, .. } = p;
        if let Err(e) = journal.finish() {
            // All steps landed; a stale journal file only costs a spurious
            // rolled-back-batch report on the next startup.
            eprintln!("provspark: warning: completed migration journal not removed: {e:#}");
        }
        done.batch = self.batches.fetch_add(1, Ordering::SeqCst) + 1;
        Ok(done)
    }

    /// Whether an interrupted ingest is parked awaiting
    /// [`recover`](Self::recover).
    pub fn has_pending(&self) -> bool {
        self.pending.lock().expect("pending migration lock poisoned").is_some()
    }

    /// Resume an interrupted [`ingest`](Self::ingest) from its journal
    /// cursor: already-committed steps are not re-run (each landed
    /// atomically), the remaining steps execute in plan order, and the
    /// returned stats describe the *whole* batch. Errors if nothing is
    /// pending; a recovery that fails again re-parks the plan, so `recover`
    /// can be retried until the underlying fault clears.
    pub fn recover(&self) -> Result<ShardedDeltaStats> {
        let _serial = self.ingest_lock.lock().expect("sharded ingest lock poisoned");
        let parked = self.pending.lock().expect("pending migration lock poisoned").take();
        match parked {
            Some(p) => self.run_steps(p),
            None => anyhow::bail!("no interrupted sharded ingest to recover"),
        }
    }

    /// Gather every shard's current state back into one combined
    /// `(Trace, Preprocessed)` — what the CLI persists after a sharded
    /// ingest (see [`merge_shards`]). Serialized against
    /// [`ingest`](Self::ingest), so it never observes the transient
    /// mid-migration window where a moving component exists on two shards.
    pub fn merged_state(&self) -> Result<(Trace, Preprocessed)> {
        let _serial = self.ingest_lock.lock().expect("sharded ingest lock poisoned");
        let parts: Vec<(Arc<Trace>, Arc<Preprocessed>)> =
            self.shards.iter().map(|s| (s.trace(), s.pre())).collect();
        merge_shards(&parts)
    }

    /// Per-shard preprocessed snapshots for routing (data only — never
    /// builds a lazy shard's engines).
    fn pre_snapshot(&self) -> Vec<Arc<Preprocessed>> {
        self.shards.iter().map(|s| s.pre()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::util::ids::{AttrValueId, OpId};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn cfg(tau: usize) -> EngineConfig {
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = tau;
        cfg
    }

    fn sample_items(trace: &Trace, n: usize) -> Vec<u64> {
        trace
            .triples
            .iter()
            .step_by(trace.len() / n + 1)
            .map(|t| t.dst.raw())
            .collect()
    }

    #[test]
    fn sharded_construction_matches_unsharded() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let cfg = cfg(400);
        let (trace, pre) = (Arc::new(trace), Arc::new(pre));
        let single =
            ProvSession::new(&cfg, Arc::clone(&trace), Arc::clone(&pre)).unwrap();
        let sharded =
            ShardedSession::new(&cfg, Arc::clone(&trace), Arc::clone(&pre), 3).unwrap();

        // Shards cover the data without overlap.
        let total: usize =
            sharded.shard_sessions().iter().map(|s| s.trace().len()).sum();
        assert_eq!(total, trace.len());
        assert!(
            sharded.shard_sessions().iter().filter(|s| !s.trace().is_empty()).count() >= 2,
            "degenerate shard balance"
        );

        let mut reqs: Vec<QueryRequest> =
            sample_items(&trace, 10).into_iter().map(QueryRequest::new).collect();
        reqs.push(QueryRequest::new(u64::MAX - 5)); // unknown
        reqs.push(reqs[0].clone().with_max_depth(2)); // capped
        for router in
            [EngineRouter::Auto, EngineRouter::Rq, EngineRouter::CcProv, EngineRouter::CsProv]
        {
            let a = single.query_many_on(router, &reqs);
            let (b, report) = sharded.query_many_report_on(router, &reqs);
            for ((req, ra), rb) in reqs.iter().zip(&a).zip(&b) {
                assert_eq!(ra.lineage, rb.lineage, "router={router} item={}", req.item);
                assert_eq!(ra.stats.engine, rb.stats.engine, "item={}", req.item);
                assert_eq!(ra.stats.truncated, rb.stats.truncated, "item={}", req.item);
            }
            assert_eq!(report.total().requests, reqs.len());
            assert!(report.per_shard.iter().filter(|s| s.requests > 0).count() >= 1);
        }
        // Routing names agree item by item.
        for &q in &sample_items(&trace, 10) {
            assert_eq!(
                single.route(EngineRouter::Auto, q),
                sharded.route(EngineRouter::Auto, q)
            );
        }
    }

    #[test]
    fn cross_shard_bridge_migrates_and_stays_equivalent() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2500, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let cfg = cfg(300);
        let (trace_arc, pre_arc) = (Arc::new(trace.clone()), Arc::new(pre));
        let single =
            ProvSession::new(&cfg, Arc::clone(&trace_arc), Arc::clone(&pre_arc)).unwrap();
        let sharded =
            ShardedSession::new(&cfg, Arc::clone(&trace_arc), Arc::clone(&pre_arc), 4)
                .unwrap();

        // Find two existing items on different shards and bridge them.
        let items = sample_items(&trace, 50);
        let a = items[0];
        let sa = sharded.shard_of(a).expect("known item");
        let b = *items
            .iter()
            .find(|&&x| sharded.shard_of(x).expect("known item") != sa)
            .expect("an item on another shard");
        let bridge = ProvTriple::new(AttrValueId(a), AttrValueId(b), OpId(0));
        let batch = TripleBatch::new(vec![bridge]);

        let d_single = single.ingest(&batch).unwrap();
        let d_sharded = sharded.ingest(&batch).unwrap();
        assert!(d_single.components_merged >= 1);
        assert_eq!(d_sharded.new_triples, 1);
        assert_eq!(d_sharded.cross_shard_merges, 1);
        assert!(d_sharded.migrated_components >= 1);
        assert!(d_sharded.migrated_triples >= 1);
        assert!(!d_sharded.rebuilt_shards.is_empty(), "a losing shard was rebuilt");
        assert_eq!(d_sharded.batch, 1);
        assert_eq!(sharded.batches_ingested(), 1);

        // Both endpoints now live on one shard…
        assert_eq!(sharded.shard_of(a), sharded.shard_of(b));
        // …and answers still match the unsharded session everywhere.
        let mut reqs: Vec<QueryRequest> =
            items.iter().copied().map(QueryRequest::new).collect();
        reqs.push(QueryRequest::new(b));
        for router in [EngineRouter::Auto, EngineRouter::Rq, EngineRouter::CsProv] {
            let x = single.query_many_on(router, &reqs);
            let y = sharded.query_many_on(router, &reqs);
            for ((req, rx), ry) in reqs.iter().zip(&x).zip(&y) {
                assert_eq!(rx.lineage, ry.lineage, "router={router} item={}", req.item);
                assert_eq!(rx.stats.engine, ry.stats.engine, "item={}", req.item);
            }
        }
        // No rows lost or duplicated across the migration.
        let total: usize =
            sharded.shard_sessions().iter().map(|s| s.trace().len()).sum();
        assert_eq!(total, trace.len() + 1);
    }

    #[test]
    fn interrupted_ingest_parks_and_recovers_to_equivalence() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2500, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let cfg_ok = cfg(300);
        // Same engine config plus a fault plan that kills the *second*
        // journal step (probe index 1) exactly once.
        let mut cfg_faulty = cfg_ok.clone();
        cfg_faulty.cluster.fault_plan = Some("io:journal:@1,seed=5".parse().unwrap());
        let (trace_arc, pre_arc) = (Arc::new(trace.clone()), Arc::new(pre));
        let single =
            ProvSession::new(&cfg_ok, Arc::clone(&trace_arc), Arc::clone(&pre_arc)).unwrap();
        let journal_file = std::env::temp_dir().join(format!(
            "provspark-sharded-recover-{}.journal",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&journal_file);
        let sharded = ShardedSession::new(
            &cfg_faulty,
            Arc::clone(&trace_arc),
            Arc::clone(&pre_arc),
            4,
        )
        .unwrap()
        .with_journal_path(&journal_file);

        // A cross-shard bridge forces a multi-step plan (winner ingest +
        // loser rebuild).
        let items = sample_items(&trace, 50);
        let a = items[0];
        let sa = sharded.shard_of(a).expect("known item");
        let b = *items
            .iter()
            .find(|&&x| sharded.shard_of(x).expect("known item") != sa)
            .expect("an item on another shard");
        let batch =
            TripleBatch::new(vec![ProvTriple::new(AttrValueId(a), AttrValueId(b), OpId(0))]);

        let err = sharded.ingest(&batch).unwrap_err();
        assert!(format!("{err:#}").contains("call recover()"), "{err:#}");
        assert!(sharded.has_pending());
        assert!(journal_file.exists(), "interrupted journal stays on disk");
        assert_eq!(sharded.batches_ingested(), 0, "interrupted batch not counted");

        // The exact @1 probe cannot re-fire (indices keep advancing), so
        // recovery completes the plan.
        let d = sharded.recover().unwrap();
        assert!(!sharded.has_pending());
        assert!(!journal_file.exists(), "completed journal is retired");
        assert_eq!(d.batch, 1);
        assert_eq!(d.cross_shard_merges, 1);
        assert!(d.journal_steps >= 2, "bridge needs winner ingest + loser rebuild");
        assert!(sharded.recover().is_err(), "nothing left to recover");

        // Converged state answers exactly like the unsharded session.
        let _ = single.ingest(&batch).unwrap();
        assert_eq!(sharded.shard_of(a), sharded.shard_of(b));
        let reqs: Vec<QueryRequest> =
            items.iter().copied().map(QueryRequest::new).collect();
        let x = single.query_many_on(EngineRouter::Auto, &reqs);
        let (y, report) = sharded.query_many_report_on(EngineRouter::Auto, &reqs);
        for ((req, rx), ry) in reqs.iter().zip(&x).zip(&y) {
            assert_eq!(rx.lineage, ry.lineage, "item={}", req.item);
        }
        assert_eq!(report.outcomes.len(), reqs.len());
        assert!(report.outcomes.iter().all(|o| *o == QueryOutcome::Full));
        let total: usize =
            sharded.shard_sessions().iter().map(|s| s.trace().len()).sum();
        assert_eq!(total, trace.len() + 1, "no rows lost or duplicated by recovery");
    }

    #[test]
    fn disjoint_shard_ingests_fan_out_in_parallel() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2500, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let cfg = cfg(300);
        let (trace_arc, pre_arc) = (Arc::new(trace.clone()), Arc::new(pre));
        let single =
            ProvSession::new(&cfg, Arc::clone(&trace_arc), Arc::clone(&pre_arc)).unwrap();
        let sharded =
            ShardedSession::new(&cfg, Arc::clone(&trace_arc), Arc::clone(&pre_arc), 4).unwrap();

        // Two sub-batches extending components on *different* shards: a
        // pure-ingest plan with no migrations — the parallel fan-out path.
        let items = sample_items(&trace, 50);
        let a = items[0];
        let sa = sharded.shard_of(a).expect("known item");
        let b = *items
            .iter()
            .find(|&&x| sharded.shard_of(x).expect("known item") != sa)
            .expect("an item on another shard");
        let batch = TripleBatch::new(vec![
            ProvTriple::new(AttrValueId(u64::MAX - 11), AttrValueId(a), OpId(0)),
            ProvTriple::new(AttrValueId(u64::MAX - 12), AttrValueId(b), OpId(0)),
        ]);
        let d = sharded.ingest(&batch).unwrap();
        assert_eq!(d.cross_shard_merges, 0);
        assert_eq!(d.journal_steps, 2, "one ingest step per touched shard");
        assert_eq!(d.per_shard.iter().filter(|x| x.is_some()).count(), 2);
        assert!(d.rebuilt_shards.is_empty());

        let _ = single.ingest(&batch).unwrap();
        let reqs: Vec<QueryRequest> =
            items.iter().copied().map(QueryRequest::new).collect();
        let x = single.query_many_on(EngineRouter::Auto, &reqs);
        let y = sharded.query_many_on(EngineRouter::Auto, &reqs);
        for ((req, rx), ry) in reqs.iter().zip(&x).zip(&y) {
            assert_eq!(rx.lineage, ry.lineage, "item={}", req.item);
        }
        let total: usize =
            sharded.shard_sessions().iter().map(|s| s.trace().len()).sum();
        assert_eq!(total, trace.len() + 2);
    }

    #[test]
    fn interrupted_parallel_ingest_parks_and_recovers() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2500, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        // The *first* journal probe fails exactly once: on the parallel
        // path all probes are drawn up front, so step 0 is re-journaled as
        // the remainder while step 1 lands.
        let mut cfg_faulty = cfg(300);
        cfg_faulty.cluster.fault_plan = Some("io:journal:@0,seed=7".parse().unwrap());
        let (trace_arc, pre_arc) = (Arc::new(trace.clone()), Arc::new(pre));
        let sharded = ShardedSession::new(
            &cfg_faulty,
            Arc::clone(&trace_arc),
            Arc::clone(&pre_arc),
            4,
        )
        .unwrap();

        let items = sample_items(&trace, 50);
        let a = items[0];
        let sa = sharded.shard_of(a).expect("known item");
        let b = *items
            .iter()
            .find(|&&x| sharded.shard_of(x).expect("known item") != sa)
            .expect("an item on another shard");
        let batch = TripleBatch::new(vec![
            ProvTriple::new(AttrValueId(u64::MAX - 21), AttrValueId(a), OpId(0)),
            ProvTriple::new(AttrValueId(u64::MAX - 22), AttrValueId(b), OpId(0)),
        ]);

        let err = sharded.ingest(&batch).unwrap_err();
        assert!(format!("{err:#}").contains("call recover()"), "{err:#}");
        assert!(sharded.has_pending());
        assert_eq!(sharded.batches_ingested(), 0);

        // The @0 probe cannot re-fire; recovery lands the parked step.
        let d = sharded.recover().unwrap();
        assert!(!sharded.has_pending());
        assert_eq!(d.batch, 1);
        assert_eq!(d.per_shard.iter().filter(|x| x.is_some()).count(), 2);
        let total: usize =
            sharded.shard_sessions().iter().map(|s| s.trace().len()).sum();
        assert_eq!(total, trace.len() + 2, "no rows lost or duplicated by recovery");
    }

    #[test]
    fn empty_batch_is_a_front_level_noop() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 4000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let sharded =
            ShardedSession::new(&cfg(100), Arc::new(trace), Arc::new(pre), 2).unwrap();
        let before: Vec<u64> =
            sharded.shard_sessions().iter().map(|s| s.epoch()).collect();
        let d = sharded.ingest(&TripleBatch::default()).unwrap();
        assert_eq!(d.batch, 1);
        assert_eq!(d.new_triples, 0);
        assert!(d.per_shard.iter().all(|s| s.is_none()));
        let after: Vec<u64> = sharded.shard_sessions().iter().map(|s| s.epoch()).collect();
        assert_eq!(before, after, "no shard epoch moves on an empty batch");
    }

    #[test]
    fn merged_state_roundtrips_through_a_new_session() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 3000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let cfg = cfg(200);
        let sharded =
            ShardedSession::new(&cfg, Arc::new(trace.clone()), Arc::new(pre), 3).unwrap();
        let (mt, mp) = sharded.merged_state().unwrap();
        assert_eq!(mt.len(), trace.len());
        // The merged state opens as a fresh session and answers like the
        // sharded one.
        let reopened = ProvSession::new(&cfg, Arc::new(mt), Arc::new(mp)).unwrap();
        for &q in &sample_items(&trace, 8) {
            let req = QueryRequest::new(q);
            assert_eq!(
                reopened.execute_on(EngineRouter::Auto, &req).lineage,
                sharded.execute_on(EngineRouter::Auto, &req).lineage,
            );
        }
    }
}
