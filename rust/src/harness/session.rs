//! `ProvSession` — the query service facade the north-star production
//! system grows from: one object owning the three engines over `Arc`-shared
//! data, a routing policy picking the cheapest engine per query, and
//! batched execution fanned across the `exec` worker threads.

use super::engines::EngineSet;
use crate::config::EngineConfig;
use crate::exec::par_map_indexed;
use crate::minispark::MiniSpark;
use crate::provenance::model::Trace;
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::{ProvenanceEngine, QueryRequest, QueryResponse};
use anyhow::Result;
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Which engine answers a request.
///
/// `Auto` routes on data shape, using component size from [`Preprocessed`]:
/// items in a *large* (Algorithm 3-partitioned) component go to CSProv,
/// whose set-lineage pruning is what makes those queries real-time; items
/// in small components go to CCProv (their component is a single set, so
/// CSProv would reduce to CCProv anyway, §2.3); unknown items go to CSProv,
/// whose node-index miss is the cheapest rejection. `Auto` never picks RQ —
/// the baseline exists to be measured against, not to serve traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineRouter {
    Rq,
    CcProv,
    CsProv,
    #[default]
    Auto,
}

impl std::str::FromStr for EngineRouter {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "rq" => Ok(EngineRouter::Rq),
            "ccprov" => Ok(EngineRouter::CcProv),
            "csprov" => Ok(EngineRouter::CsProv),
            "auto" => Ok(EngineRouter::Auto),
            other => anyhow::bail!("unknown engine {other:?} (rq|ccprov|csprov|auto)"),
        }
    }
}

impl std::fmt::Display for EngineRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineRouter::Rq => "rq",
            EngineRouter::CcProv => "ccprov",
            EngineRouter::CsProv => "csprov",
            EngineRouter::Auto => "auto",
        })
    }
}

/// A query session: the three engines behind one routed, batchable front.
pub struct ProvSession {
    sc: MiniSpark,
    engines: EngineSet,
    router: EngineRouter,
    /// Component ids that were Algorithm 3-partitioned (the `Auto` key).
    large: FxHashSet<u64>,
}

impl ProvSession {
    /// Open a session on its own minispark context.
    pub fn new(cfg: &EngineConfig, trace: Arc<Trace>, pre: Arc<Preprocessed>) -> Result<Self> {
        let sc = MiniSpark::new(cfg.cluster.clone());
        Self::with_context(&sc, cfg, trace, pre)
    }

    /// Open a session on an existing context (shares its worker pool,
    /// metrics and config).
    pub fn with_context(
        sc: &MiniSpark,
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
    ) -> Result<Self> {
        let engines = EngineSet::build(sc, trace, pre, cfg)?;
        let large: FxHashSet<u64> =
            engines.pre().large_components.iter().map(|&(cc, _, _)| cc).collect();
        Ok(Self { sc: sc.clone(), engines, router: EngineRouter::Auto, large })
    }

    /// Set the default routing policy (builder-style).
    pub fn with_router(mut self, router: EngineRouter) -> Self {
        self.router = router;
        self
    }

    pub fn router(&self) -> EngineRouter {
        self.router
    }

    pub fn context(&self) -> &MiniSpark {
        &self.sc
    }

    pub fn engines(&self) -> &EngineSet {
        &self.engines
    }

    pub fn trace(&self) -> &Arc<Trace> {
        self.engines.trace()
    }

    pub fn pre(&self) -> &Arc<Preprocessed> {
        self.engines.pre()
    }

    /// Resolve a routing policy for one item to a concrete engine.
    pub fn resolve(&self, router: EngineRouter, item: u64) -> &dyn ProvenanceEngine {
        match router {
            EngineRouter::Rq => &self.engines.rq,
            EngineRouter::CcProv => &self.engines.ccprov,
            EngineRouter::CsProv => &self.engines.csprov,
            EngineRouter::Auto => match self.engines.pre().cc_of.get(&item) {
                Some(cc) if self.large.contains(cc) => &self.engines.csprov,
                Some(_) => &self.engines.ccprov,
                None => &self.engines.csprov,
            },
        }
    }

    /// Answer one request with the session's default router.
    pub fn execute(&self, req: &QueryRequest) -> QueryResponse {
        self.execute_on(self.router, req)
    }

    /// Answer one request with an explicit routing policy.
    pub fn execute_on(&self, router: EngineRouter, req: &QueryRequest) -> QueryResponse {
        self.resolve(router, req.item).execute(req)
    }

    /// Answer a batch concurrently on the `exec` worker threads (one logical
    /// worker per configured executor), preserving request order. Each
    /// response's [`QueryStats`](crate::provenance::query::QueryStats) is
    /// still attributed to its own request — the per-query counters don't
    /// interleave the way the engine-wide metrics do under concurrency.
    pub fn query_many(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        self.query_many_on(self.router, reqs)
    }

    /// [`query_many`](Self::query_many) with an explicit routing policy.
    pub fn query_many_on(
        &self,
        router: EngineRouter,
        reqs: &[QueryRequest],
    ) -> Vec<QueryResponse> {
        let parallelism = self.sc.config().executors.max(1);
        par_map_indexed(reqs, parallelism, |_, req| self.execute_on(router, req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    fn session(tau: usize) -> ProvSession {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = tau;
        ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre)).unwrap()
    }

    #[test]
    fn router_parses_and_displays() {
        for (s, r) in [
            ("rq", EngineRouter::Rq),
            ("ccprov", EngineRouter::CcProv),
            ("CSPROV", EngineRouter::CsProv),
            ("auto", EngineRouter::Auto),
        ] {
            assert_eq!(s.parse::<EngineRouter>().unwrap(), r);
        }
        assert!("spark".parse::<EngineRouter>().is_err());
        assert_eq!(EngineRouter::Auto.to_string(), "auto");
    }

    #[test]
    fn auto_routes_by_component_size() {
        let s = session(1000);
        let pre = Arc::clone(s.pre());
        let large: FxHashSet<u64> =
            pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
        let lc_item = s
            .trace()
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| large.contains(&pre.cc_of[n]))
            .expect("large-component item");
        let sc_item = s
            .trace()
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| !large.contains(&pre.cc_of[n]))
            .expect("small-component item");
        assert_eq!(s.resolve(EngineRouter::Auto, lc_item).name(), "csprov");
        assert_eq!(s.resolve(EngineRouter::Auto, sc_item).name(), "ccprov");
        // Unknown items: cheapest rejection, never RQ.
        assert_eq!(s.resolve(EngineRouter::Auto, u64::MAX - 7).name(), "csprov");
        // Explicit policies resolve to themselves.
        assert_eq!(s.resolve(EngineRouter::Rq, lc_item).name(), "rq");
    }

    #[test]
    fn batched_equals_sequential() {
        let s = session(500);
        let reqs: Vec<QueryRequest> = s
            .trace()
            .triples
            .iter()
            .step_by(s.trace().len() / 12 + 1)
            .map(|t| QueryRequest::new(t.dst.raw()))
            .collect();
        assert!(reqs.len() >= 8);
        let batched = s.query_many(&reqs);
        for (req, resp) in reqs.iter().zip(&batched) {
            let seq = s.execute(req);
            assert_eq!(resp.lineage, seq.lineage, "item {}", req.item);
            assert_eq!(resp.stats.engine, seq.stats.engine);
            assert_eq!(resp.stats.partitions_scanned, seq.stats.partitions_scanned);
            assert_eq!(resp.stats.rows_examined, seq.stats.rows_examined);
        }
    }
}
