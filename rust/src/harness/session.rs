//! `ProvSession` — the query service facade the north-star production
//! system grows from: one object owning the three engines over `Arc`-shared
//! data, a routing policy picking the cheapest engine per query, batched
//! execution fanned across the `exec` worker threads, and **live
//! ingestion**: [`ProvSession::ingest`] applies a [`TripleBatch`] to an
//! incrementally maintained index and swaps in a new engine epoch while
//! in-flight query batches keep answering over the previous one.
//!
//! # Epochs
//!
//! The session's engines live behind `RwLock<Arc<EngineSet>>`. Every query
//! (and every `query_many` batch) clones the current `Arc` once and runs
//! entirely against that epoch — a concurrent ingest builds the next
//! [`EngineSet`] off to the side (via [`EngineSet::absorb`], which routes
//! only the delta into the existing datasets) and then swaps the `Arc`.
//! Readers never block ingestion and never observe a half-applied batch;
//! the old epoch is dropped when its last in-flight query finishes.

use super::engines::EngineSet;
use crate::config::EngineConfig;
use crate::exec::{panic_message, par_map_indexed};
use crate::minispark::MiniSpark;
use crate::provenance::incremental::{DeltaStats, IncrementalIndex, TripleBatch};
use crate::provenance::model::Trace;
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::{
    Completeness, Lineage, ProvenanceEngine, QueryOutcome, QueryRequest, QueryResponse,
    QueryStats,
};
use crate::provenance::store::SegmentedPre;
use crate::workflow::curation::text_curation_workflow;
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::SplitSet;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, RwLock};

/// Execute one request under supervision: a panicking engine (a quarantined
/// task surfacing through `run_job`, or an injected fault that outlived its
/// retry budget) is caught and the request retried up to
/// [`QueryRequest::retries`] more times. When every attempt dies, the
/// caller gets a well-formed *failed* response instead of a crash: an empty
/// lineage whose [`Completeness`] says nothing was proven
/// (`exhausted = false`), classified [`QueryOutcome::Failed`] — so one
/// poisoned item degrades one answer, never the batch or the process.
pub fn execute_supervised(
    engine: &dyn ProvenanceEngine,
    req: &QueryRequest,
) -> (QueryResponse, QueryOutcome) {
    let attempts = req.retries.saturating_add(1);
    let mut last_panic = String::new();
    for _ in 0..attempts {
        match catch_unwind(AssertUnwindSafe(|| engine.execute(req))) {
            Ok(resp) => {
                let outcome = QueryOutcome::of(&resp.stats);
                return (resp, outcome);
            }
            Err(payload) => last_panic = panic_message(payload.as_ref()),
        }
    }
    let mut stats = QueryStats::new(engine.name());
    stats.completeness =
        Completeness { rounds_done: 0, frontier_remaining: 0, exhausted: false };
    eprintln!(
        "provspark: query {} failed after {attempts} attempt(s): {last_panic}",
        req.item
    );
    (QueryResponse { lineage: Lineage::empty(req.item), stats }, QueryOutcome::Failed)
}

/// Which engine answers a request.
///
/// `Auto` routes on data shape, using component size from [`Preprocessed`]
/// (see [`EngineSet::route`] for the policy): large-component items →
/// CSProv, small-component items → CCProv, unknown items → CSProv's cheap
/// index miss — never RQ.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineRouter {
    Rq,
    CcProv,
    CsProv,
    #[default]
    Auto,
}

impl std::str::FromStr for EngineRouter {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "rq" => Ok(EngineRouter::Rq),
            "ccprov" => Ok(EngineRouter::CcProv),
            "csprov" => Ok(EngineRouter::CsProv),
            "auto" => Ok(EngineRouter::Auto),
            other => anyhow::bail!("unknown engine {other:?} (rq|ccprov|csprov|auto)"),
        }
    }
}

impl std::fmt::Display for EngineRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineRouter::Rq => "rq",
            EngineRouter::CcProv => "ccprov",
            EngineRouter::CsProv => "csprov",
            EngineRouter::Auto => "auto",
        })
    }
}

/// A query session: the three engines behind one routed, batchable,
/// ingest-capable front.
///
/// ```
/// use provspark::config::EngineConfig;
/// use provspark::harness::{EngineRouter, ProvSession};
/// use provspark::provenance::pipeline::{preprocess, WccImpl};
/// use provspark::provenance::query::QueryRequest;
/// use provspark::workflow::generator::{generate, GeneratorConfig};
/// use std::sync::Arc;
///
/// // Generate a tiny trace, preprocess it, open a session.
/// let (trace, graph, splits) =
///     generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
/// let pre = preprocess(&trace, &graph, &splits, 100, 50, WccImpl::Driver);
/// let mut cfg = EngineConfig::default();
/// cfg.cluster.job_overhead_us = 0;
/// let session = ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre)).unwrap();
///
/// // Query one derived item; the Auto router picks the cheapest engine.
/// let item = session.trace().triples[0].dst.raw();
/// let resp = session.execute_on(EngineRouter::Auto, &QueryRequest::new(item));
/// assert_eq!(resp.lineage.query, item);
/// assert!(resp.stats.engine == "ccprov" || resp.stats.engine == "csprov");
/// ```
/// The session's engine state: raw data awaiting the first use (lazy
/// open), or the built engines. Lazy sessions let a sharded front hold
/// many shards open while only the queried ones pay construction (and,
/// under a memory budget, spill) costs.
enum SessionState {
    /// Registered but unbuilt: the first [`ProvSession::engines`] call
    /// builds the engine set from this data.
    Pending { trace: Arc<Trace>, pre: Arc<Preprocessed> },
    /// Current engine epoch; `Arc`-cloned per query, swapped per ingest.
    Built(Arc<EngineSet>),
}

pub struct ProvSession {
    sc: MiniSpark,
    cfg: EngineConfig,
    router: EngineRouter,
    state: RwLock<SessionState>,
    /// The incrementally maintained index (lazily cloned from the current
    /// epoch on first ingest; serializes ingestion).
    index: Mutex<Option<IncrementalIndex>>,
    /// Workflow the index re-partitions dirty components against.
    workflow: (DependencyGraph, SplitSet),
    /// The segmented store a zero-copy session pages triples from
    /// ([`with_context_segmented`](Self::with_context_segmented)); the
    /// first ingest materializes the full index from it.
    segmented: Option<Arc<SegmentedPre>>,
}

impl ProvSession {
    /// Open a session on its own minispark context.
    pub fn new(cfg: &EngineConfig, trace: Arc<Trace>, pre: Arc<Preprocessed>) -> Result<Self> {
        let sc = MiniSpark::new(cfg.cluster.clone());
        Self::with_context(&sc, cfg, trace, pre)
    }

    /// Open a session on an existing context (shares its worker pool,
    /// metrics and config).
    pub fn with_context(
        sc: &MiniSpark,
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
    ) -> Result<Self> {
        let engines = EngineSet::build(sc, trace, pre, cfg)?;
        Ok(Self {
            sc: sc.clone(),
            cfg: cfg.clone(),
            router: EngineRouter::Auto,
            state: RwLock::new(SessionState::Built(Arc::new(engines))),
            index: Mutex::new(None),
            workflow: text_curation_workflow(),
            segmented: None,
        })
    }

    /// Open a session *zero-copy* over a segmented preprocessed store
    /// (v4/v5): the engines demand-page triple partitions straight from
    /// the file ([`EngineSet::build_from_segments`]), so opening costs one
    /// header + the small index sections, not the whole store. Intended
    /// for budgeted contexts; without a memory budget the paged partitions
    /// simply fault in on first touch and stay resident.
    ///
    /// The first [`ingest`](Self::ingest) materializes the full index from
    /// the store (the incremental maintainer needs the whole snapshot);
    /// queries before and after are unaffected.
    pub fn with_context_segmented(
        sc: &MiniSpark,
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        seg: Arc<SegmentedPre>,
    ) -> Result<Self> {
        let engines = EngineSet::build_from_segments(sc, trace, Arc::clone(&seg), cfg)?;
        Ok(Self {
            sc: sc.clone(),
            cfg: cfg.clone(),
            router: EngineRouter::Auto,
            state: RwLock::new(SessionState::Built(Arc::new(engines))),
            index: Mutex::new(None),
            workflow: text_curation_workflow(),
            segmented: Some(seg),
        })
    }

    /// Open a session *lazily*: register the data but defer engine
    /// construction (partitioning, and spilling under a memory budget)
    /// until the first call that needs the engines. Accessors that only
    /// need the data ([`trace`](Self::trace), [`pre`](Self::pre),
    /// [`epoch`](Self::epoch)) never trigger the build.
    ///
    /// A deferred build that fails (e.g. spill IO) panics at the
    /// triggering call; under the supervised query paths that panic is
    /// caught and surfaces as a per-item [`QueryOutcome::Failed`].
    pub fn with_context_lazy(
        sc: &MiniSpark,
        cfg: &EngineConfig,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
    ) -> Self {
        Self {
            sc: sc.clone(),
            cfg: cfg.clone(),
            router: EngineRouter::Auto,
            state: RwLock::new(SessionState::Pending { trace, pre }),
            index: Mutex::new(None),
            workflow: text_curation_workflow(),
            segmented: None,
        }
    }

    /// Whether the engines have been built yet (always true after an eager
    /// open; flips on first use after [`with_context_lazy`]).
    pub fn is_built(&self) -> bool {
        matches!(&*self.state.read().expect("session state lock poisoned"), SessionState::Built(_))
    }

    /// Set the default routing policy (builder-style).
    pub fn with_router(mut self, router: EngineRouter) -> Self {
        self.router = router;
        self
    }

    /// Set the workflow graph + splits used when ingestion re-partitions a
    /// dirty component (builder-style; defaults to the text-curation
    /// workflow every generator trace is drawn from).
    ///
    /// **Contract**: this must be the workflow the index was preprocessed
    /// with. [`Preprocessed`] records a workflow fingerprint (persisted in
    /// the v3 store header), and the first [`ingest`](Self::ingest) fails
    /// loudly on a mismatch; indexes loaded from legacy v1/v2 files carry
    /// no fingerprint, and for those a wrong workflow silently breaks the
    /// incremental ≡ from-scratch equivalence.
    pub fn with_workflow(mut self, graph: DependencyGraph, splits: SplitSet) -> Self {
        self.workflow = (graph, splits);
        self
    }

    pub fn router(&self) -> EngineRouter {
        self.router
    }

    /// Fingerprint of the workflow this session re-partitions dirty
    /// components against on ingest
    /// ([`crate::workflow::workflow_fingerprint`]) — what a recorded
    /// [`Preprocessed::workflow_fingerprint`] must match for
    /// [`ingest`](Self::ingest) to proceed. The sharded front uses this to
    /// preflight every touched shard *before* mutating any of them.
    ///
    /// [`Preprocessed::workflow_fingerprint`]: crate::provenance::pipeline::Preprocessed::workflow_fingerprint
    pub fn workflow_fingerprint(&self) -> u64 {
        crate::workflow::workflow_fingerprint(&self.workflow.0, &self.workflow.1)
    }

    pub fn context(&self) -> &MiniSpark {
        &self.sc
    }

    /// The engine configuration this session was opened with (τ, closure
    /// backend, cluster shape) — every epoch inherits it.
    pub fn engine_config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Snapshot the current engine epoch, building it first if the session
    /// was opened lazily. The returned `Arc` stays valid — and internally
    /// consistent — for as long as the caller holds it, even across
    /// concurrent [`ingest`](Self::ingest) calls.
    pub fn engines(&self) -> Arc<EngineSet> {
        if let SessionState::Built(set) = &*self.state.read().expect("session state lock poisoned")
        {
            return Arc::clone(set);
        }
        let mut guard = self.state.write().expect("session state lock poisoned");
        // Double-checked: another thread may have built while we waited.
        if let SessionState::Built(set) = &*guard {
            return Arc::clone(set);
        }
        let SessionState::Pending { trace, pre } = &*guard else {
            unreachable!("state is Pending when not Built")
        };
        let set = match EngineSet::build(&self.sc, Arc::clone(trace), Arc::clone(pre), &self.cfg) {
            Ok(set) => Arc::new(set),
            // Panic at the triggering call; the supervised query paths
            // catch this and fail the item, not the process.
            Err(e) => panic!("building engines lazily: {e:#}"),
        };
        *guard = SessionState::Built(Arc::clone(&set));
        set
    }

    /// The current epoch's trace.
    ///
    /// Each call takes its own epoch snapshot — a concurrent
    /// [`ingest`](Self::ingest) may land between two accessor calls. When
    /// trace, index, and engines must describe **one** ingestion state,
    /// snapshot once via [`engines`](Self::engines) and read all three off
    /// that [`EngineSet`]. Never triggers a lazy build.
    pub fn trace(&self) -> Arc<Trace> {
        match &*self.state.read().expect("session state lock poisoned") {
            SessionState::Pending { trace, .. } => Arc::clone(trace),
            SessionState::Built(set) => Arc::clone(set.trace()),
        }
    }

    /// The current epoch's preprocessed data (same single-accessor snapshot
    /// semantics as [`trace`](Self::trace)). Never triggers a lazy build.
    pub fn pre(&self) -> Arc<Preprocessed> {
        match &*self.state.read().expect("session state lock poisoned") {
            SessionState::Pending { pre, .. } => Arc::clone(pre),
            SessionState::Built(set) => Arc::clone(set.pre()),
        }
    }

    /// Batches ingested since the session's underlying full preprocess.
    pub fn epoch(&self) -> u64 {
        self.pre().epoch
    }

    /// Name of the engine a routing policy resolves to for one item
    /// (`"rq" | "ccprov" | "csprov"`), without executing anything.
    pub fn route(&self, router: EngineRouter, item: u64) -> &'static str {
        self.engines().route(router, item).name()
    }

    /// Answer one request with the session's default router.
    pub fn execute(&self, req: &QueryRequest) -> QueryResponse {
        self.execute_on(self.router, req)
    }

    /// Answer one request with an explicit routing policy.
    pub fn execute_on(&self, router: EngineRouter, req: &QueryRequest) -> QueryResponse {
        self.engines().route(router, req.item).execute(req)
    }

    /// Answer a batch concurrently on the `exec` worker threads (one logical
    /// worker per configured executor), preserving request order. The whole
    /// batch runs against **one** engine epoch (snapshotted on entry), so a
    /// concurrent ingest never splits a batch across index versions; each
    /// response's [`QueryStats`](crate::provenance::query::QueryStats) is
    /// still attributed to its own request — the per-query counters don't
    /// interleave the way the engine-wide metrics do under concurrency.
    pub fn query_many(&self, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
        self.query_many_on(self.router, reqs)
    }

    /// [`query_many`](Self::query_many) with an explicit routing policy.
    pub fn query_many_on(
        &self,
        router: EngineRouter,
        reqs: &[QueryRequest],
    ) -> Vec<QueryResponse> {
        let epoch = self.engines();
        let parallelism = self.sc.config().executors.max(1);
        par_map_indexed(reqs, parallelism, |_, req| epoch.route(router, req.item).execute(req))
    }

    /// [`query_many`](Self::query_many) with per-item supervision: each
    /// request runs through [`execute_supervised`], so a failing item yields
    /// a `(empty response, Failed)` pair instead of sinking the batch, and
    /// every answer carries its [`QueryOutcome`] classification
    /// (full / partial-under-deadline / failed).
    pub fn query_many_outcomes(
        &self,
        reqs: &[QueryRequest],
    ) -> Vec<(QueryResponse, QueryOutcome)> {
        self.query_many_outcomes_on(self.router, reqs)
    }

    /// [`query_many_outcomes`](Self::query_many_outcomes) with an explicit
    /// routing policy.
    pub fn query_many_outcomes_on(
        &self,
        router: EngineRouter,
        reqs: &[QueryRequest],
    ) -> Vec<(QueryResponse, QueryOutcome)> {
        let epoch = self.engines();
        let parallelism = self.sc.config().executors.max(1);
        par_map_indexed(reqs, parallelism, |_, req| {
            execute_supervised(epoch.route(router, req.item), req)
        })
    }

    /// Ingest a batch of new provenance triples: apply it to the
    /// incrementally maintained index
    /// ([`IncrementalIndex::apply`] — cost proportional to the delta and
    /// its dirty components, not the index), derive the next engine epoch
    /// by absorbing the delta into the current datasets
    /// ([`EngineSet::absorb`]), and swap it in. Queries running concurrently
    /// keep their epoch; queries started after this returns see the batch.
    ///
    /// Ingestions are serialized; queries are never blocked by one (beyond
    /// the final pointer swap). Dirty components are re-partitioned against
    /// the session's workflow — the default (text-curation) is correct for
    /// every generator-produced trace; an index preprocessed under a custom
    /// workflow must set it via [`with_workflow`](Self::with_workflow)
    /// **before** the first ingest.
    ///
    /// ```
    /// use provspark::config::EngineConfig;
    /// use provspark::harness::ProvSession;
    /// use provspark::provenance::incremental::TripleBatch;
    /// use provspark::provenance::model::Trace;
    /// use provspark::provenance::pipeline::{preprocess, WccImpl};
    /// use provspark::workflow::generator::{generate, GeneratorConfig};
    /// use std::sync::Arc;
    ///
    /// let (full, graph, splits) =
    ///     generate(&GeneratorConfig { scale_divisor: 5000, ..Default::default() });
    /// let cut = full.len() * 9 / 10;
    /// let base = Trace::new(full.triples[..cut].to_vec());
    /// let pre = preprocess(&base, &graph, &splits, 100, 50, WccImpl::Driver);
    /// let mut cfg = EngineConfig::default();
    /// cfg.cluster.job_overhead_us = 0;
    /// let session = ProvSession::new(&cfg, Arc::new(base), Arc::new(pre)).unwrap();
    ///
    /// // The last 10% of the trace arrives as a live delta.
    /// let stats = session.ingest(&TripleBatch::new(full.triples[cut..].to_vec())).unwrap();
    /// assert_eq!(stats.epoch, 1);
    /// assert_eq!(session.epoch(), 1);
    /// assert_eq!(session.trace().len(), full.len());
    /// ```
    pub fn ingest(&self, batch: &TripleBatch) -> Result<DeltaStats> {
        let mut guard = self.index.lock().expect("session ingest lock poisoned");
        if guard.is_none() {
            let cur = self.engines();
            let (graph, splits) = self.workflow.clone();
            // A zero-copy (segmented) session's epoch holds only the light
            // pre — the incremental maintainer needs the whole snapshot, so
            // the first ingest pays the full segment read once.
            let pre = match &self.segmented {
                Some(seg) if cur.pre().cc_triples.len() != cur.trace().len() => seg.load_all()?,
                _ => cur.pre().as_ref().clone(),
            };
            *guard =
                Some(IncrementalIndex::new(cur.trace().as_ref().clone(), pre, graph, splits)?);
        }
        let index = guard.as_mut().expect("index initialized above");
        // Fault atomicity: the swap below is the *only* externally visible
        // effect. If anything before it fails — an `apply`/`absorb` error,
        // or a panic out of a quarantined worker — the maintained index may
        // hold a half-applied batch, so it is discarded: served state is
        // untouched (epochs are immutable), and the next ingest lazily
        // rebuilds the index *from the served state*. Each ingest is
        // therefore all-or-nothing, which is what the sharded front's
        // migration journal replays against.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let delta = index.apply(batch)?;
            let (trace, pre) = index.snapshot();
            let prev = self.engines();
            let next = EngineSet::absorb(&prev, trace, pre, &delta)?;
            *self.state.write().expect("session state lock poisoned") =
                SessionState::Built(Arc::new(next));
            Ok(delta.stats)
        }));
        match outcome {
            Ok(Ok(stats)) => Ok(stats),
            Ok(Err(e)) => {
                *guard = None;
                Err(e)
            }
            Err(payload) => {
                *guard = None;
                anyhow::bail!("ingest panicked: {}", panic_message(payload.as_ref()))
            }
        }
    }

    /// Replace the session's entire data state: rebuild the engines over
    /// `trace`/`pre` ([`EngineSet::build`] — full engine construction, not
    /// a delta absorb) and swap them in as the next epoch. The maintained
    /// incremental index is discarded; the next [`ingest`](Self::ingest)
    /// lazily reconstructs it from the new state.
    ///
    /// In-flight query batches keep their previous epoch, exactly as under
    /// `ingest`. This is the shard-migration primitive: when a cross-shard
    /// component merge moves a component *off* a shard
    /// (`ShardedSession::ingest`), the losing shard's session is rebuilt
    /// over its kept remainder — datasets have an append/patch path but no
    /// removal path, so shrinking a shard is a rebuild of what remains
    /// (bounded by the smaller, losing side).
    pub fn replace_state(&self, trace: Arc<Trace>, pre: Arc<Preprocessed>) -> Result<()> {
        // Same lock order as `ingest` (index, then state write): the index
        // must be invalidated together with the swap, or a racing ingest
        // could re-apply a stale index over the replaced state. Like
        // `ingest`, a failure (error or panic) before the swap leaves the
        // served state untouched and only costs the cached index — the
        // build is pure construction off to the side.
        let mut guard = self.index.lock().expect("session ingest lock poisoned");
        let outcome =
            catch_unwind(AssertUnwindSafe(|| EngineSet::build(&self.sc, trace, pre, &self.cfg)));
        match outcome {
            Ok(Ok(next)) => {
                *self.state.write().expect("session state lock poisoned") =
                    SessionState::Built(Arc::new(next));
                *guard = None;
                Ok(())
            }
            Ok(Err(e)) => {
                *guard = None;
                Err(e)
            }
            Err(payload) => {
                *guard = None;
                anyhow::bail!("replace_state panicked: {}", panic_message(payload.as_ref()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};
    use rustc_hash::FxHashSet;

    fn session(tau: usize) -> ProvSession {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = tau;
        ProvSession::new(&cfg, Arc::new(trace), Arc::new(pre)).unwrap()
    }

    #[test]
    fn router_parses_and_displays() {
        for (s, r) in [
            ("rq", EngineRouter::Rq),
            ("ccprov", EngineRouter::CcProv),
            ("CSPROV", EngineRouter::CsProv),
            ("auto", EngineRouter::Auto),
        ] {
            assert_eq!(s.parse::<EngineRouter>().unwrap(), r);
        }
        assert!("spark".parse::<EngineRouter>().is_err());
        assert_eq!(EngineRouter::Auto.to_string(), "auto");
    }

    #[test]
    fn auto_routes_by_component_size() {
        let s = session(1000);
        let pre = s.pre();
        let large: FxHashSet<u64> =
            pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
        let lc_item = s
            .trace()
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| large.contains(&pre.cc_of[n]))
            .expect("large-component item");
        let sc_item = s
            .trace()
            .triples
            .iter()
            .map(|t| t.dst.raw())
            .find(|n| !large.contains(&pre.cc_of[n]))
            .expect("small-component item");
        assert_eq!(s.route(EngineRouter::Auto, lc_item), "csprov");
        assert_eq!(s.route(EngineRouter::Auto, sc_item), "ccprov");
        // Unknown items: cheapest rejection, never RQ.
        assert_eq!(s.route(EngineRouter::Auto, u64::MAX - 7), "csprov");
        // Explicit policies resolve to themselves.
        assert_eq!(s.route(EngineRouter::Rq, lc_item), "rq");
    }

    #[test]
    fn batched_equals_sequential() {
        let s = session(500);
        let reqs: Vec<QueryRequest> = s
            .trace()
            .triples
            .iter()
            .step_by(s.trace().triples.len() / 12 + 1)
            .map(|t| QueryRequest::new(t.dst.raw()))
            .collect();
        assert!(reqs.len() >= 8);
        let batched = s.query_many(&reqs);
        for (req, resp) in reqs.iter().zip(&batched) {
            let seq = s.execute(req);
            assert_eq!(resp.lineage, seq.lineage, "item {}", req.item);
            assert_eq!(resp.stats.engine, seq.stats.engine);
            assert_eq!(resp.stats.partitions_scanned, seq.stats.partitions_scanned);
            assert_eq!(resp.stats.rows_examined, seq.stats.rows_examined);
        }
    }

    #[test]
    fn supervised_execution_retries_and_isolates_failures() {
        use std::sync::atomic::{AtomicU32, Ordering};

        /// Panics on the first `fail_first` calls, then answers.
        struct Flaky {
            fail_first: u32,
            calls: AtomicU32,
        }
        impl ProvenanceEngine for Flaky {
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn execute(&self, req: &QueryRequest) -> QueryResponse {
                if self.calls.fetch_add(1, Ordering::SeqCst) < self.fail_first {
                    panic!("injected engine crash");
                }
                QueryResponse {
                    lineage: Lineage::empty(req.item),
                    stats: QueryStats::new("flaky"),
                }
            }
        }

        // Two failures, two retries: the third attempt answers.
        let flaky = Flaky { fail_first: 2, calls: AtomicU32::new(0) };
        let (resp, outcome) =
            execute_supervised(&flaky, &QueryRequest::new(7).with_retries(2));
        assert_eq!(outcome, QueryOutcome::Full);
        assert_eq!(resp.lineage.query, 7);
        assert_eq!(flaky.calls.load(Ordering::SeqCst), 3);

        // Budget exhausted: a well-formed failed answer, no crash.
        let dead = Flaky { fail_first: u32::MAX, calls: AtomicU32::new(0) };
        let (resp, outcome) =
            execute_supervised(&dead, &QueryRequest::new(9).with_retries(1));
        assert_eq!(outcome, QueryOutcome::Failed);
        assert!(resp.lineage.is_empty());
        assert!(!resp.stats.completeness.exhausted);
        assert_eq!(dead.calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn batched_outcomes_classify_deadline_cuts() {
        use std::time::Duration;
        let s = session(500);
        let items: Vec<u64> = s
            .trace()
            .triples
            .iter()
            .step_by(s.trace().triples.len() / 6 + 1)
            .map(|t| t.dst.raw())
            .collect();
        // Generous deadlines: everything completes, outcomes are Full and
        // answers match the unsupervised batch path.
        let reqs: Vec<QueryRequest> = items
            .iter()
            .map(|&q| QueryRequest::new(q).with_deadline(Duration::from_secs(3600)))
            .collect();
        let plain = s.query_many(&reqs);
        let supervised = s.query_many_outcomes(&reqs);
        for ((resp, outcome), want) in supervised.iter().zip(&plain) {
            assert_eq!(*outcome, QueryOutcome::Full);
            assert_eq!(resp.lineage, want.lineage);
        }
        // Zero deadlines: partial answers with an honest bound, and each
        // partial lineage is a prefix (subset) of the full one.
        let cut: Vec<QueryRequest> =
            items.iter().map(|&q| QueryRequest::new(q).with_deadline(Duration::ZERO)).collect();
        for ((resp, outcome), full) in s.query_many_outcomes(&cut).iter().zip(&plain) {
            assert_eq!(*outcome, QueryOutcome::Partial);
            assert!(!resp.stats.completeness.exhausted);
            assert!(resp.lineage.triples.len() <= full.lineage.triples.len());
            let full_set: FxHashSet<_> = full.lineage.triples.iter().collect();
            assert!(resp.lineage.triples.iter().all(|t| full_set.contains(t)));
        }
    }

    #[test]
    fn lazy_sessions_build_on_first_use() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 150, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        let sc = MiniSpark::new(cfg.cluster.clone());
        let trace = Arc::new(trace);
        let pre = Arc::new(pre);
        let s = ProvSession::with_context_lazy(&sc, &cfg, Arc::clone(&trace), Arc::clone(&pre));
        assert!(!s.is_built());
        // Data accessors answer without triggering the build.
        assert_eq!(s.trace().len(), trace.len());
        assert_eq!(s.epoch(), 0);
        assert!(!s.is_built());
        // The first query builds; answers match an eager session.
        let q = trace.triples[0].dst.raw();
        let resp = s.execute(&QueryRequest::new(q));
        assert!(s.is_built());
        let eager =
            ProvSession::with_context(&sc, &cfg, Arc::clone(&trace), Arc::clone(&pre)).unwrap();
        assert_eq!(resp.lineage, eager.execute(&QueryRequest::new(q)).lineage);
    }

    #[test]
    fn ingest_swaps_epochs_and_serves_new_data() {
        let (full, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let cut = full.len() * 9 / 10;
        let base = Trace::new(full.triples[..cut].to_vec());
        let pre = preprocess(&base, &g, &splits, 150, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = 200;
        let s = ProvSession::new(&cfg, Arc::new(base), Arc::new(pre)).unwrap();
        assert_eq!(s.epoch(), 0);

        // A pre-ingest snapshot keeps answering over the old epoch.
        let old_epoch = s.engines();
        let old_len = old_epoch.trace().len();

        let stats =
            s.ingest(&TripleBatch::new(full.triples[cut..].to_vec())).unwrap();
        assert_eq!(stats.epoch, 1);
        assert_eq!(stats.new_triples, full.len() - cut);
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.trace().len(), full.len());
        assert_eq!(old_epoch.trace().len(), old_len, "in-flight epoch unchanged");

        // Post-ingest queries agree with a from-scratch session over the
        // concatenated trace, on every routing policy.
        let (g2, s2) = crate::workflow::curation::text_curation_workflow();
        let scratch_pre = preprocess(&full, &g2, &s2, 150, 100, WccImpl::Driver);
        let scratch =
            ProvSession::new(&cfg, Arc::new(full), Arc::new(scratch_pre)).unwrap();
        let items: Vec<u64> = scratch
            .trace()
            .triples
            .iter()
            .step_by(scratch.trace().triples.len() / 10 + 1)
            .map(|t| t.dst.raw())
            .collect();
        for router in
            [EngineRouter::Rq, EngineRouter::CcProv, EngineRouter::CsProv, EngineRouter::Auto]
        {
            for &q in &items {
                let req = QueryRequest::new(q);
                let a = s.execute_on(router, &req);
                let b = scratch.execute_on(router, &req);
                assert_eq!(a.lineage, b.lineage, "router={router} q={q}");
                assert_eq!(a.stats.engine, b.stats.engine, "router={router} q={q}");
            }
        }
    }
}
