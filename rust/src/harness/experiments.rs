//! Experiment drivers that regenerate the paper's evaluation artifacts:
//! Table 9 (connected-set statistics), Tables 10–12 (query latencies per
//! class and scale) and the §4-Discussion point-query drill-down.

use super::classes::{select_queries, QueryClass};
use super::session::{EngineRouter, ProvSession};
use crate::benchkit::Table;
use crate::config::EngineConfig;
use crate::provenance::model::Trace;
use crate::provenance::pipeline::{preprocess, Preprocessed, WccImpl};
use crate::provenance::query::{ProvenanceEngine, QueryRequest};
use crate::util::fmt::{human_count, human_duration};
use crate::workflow::generator::{generate, GeneratorConfig};
use anyhow::Result;
use rustc_hash::FxHashMap;
use std::sync::Arc;
use std::time::Instant;

/// Knobs for the table drivers.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Generator scale divisor (1 = the paper's full 10M-element base).
    pub divisor: usize,
    /// Replication factors, one table column each (paper: 1, 9, 24, 48 →
    /// 10M/100M/250M/500M).
    pub replications: Vec<usize>,
    /// Queries per class (paper: 10).
    pub queries_per_class: usize,
    /// Algorithm 3 θ (paper: 25 000 at divisor 1 — pass a scaled value).
    pub theta: usize,
    /// Table 9 "big set" bound (paper: 1000 at divisor 1).
    pub big_threshold: usize,
    pub seed: u64,
    pub engine: EngineConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        let divisor = 10;
        Self {
            divisor,
            replications: vec![1, 9, 24, 48],
            queries_per_class: 10,
            theta: (25_000 / divisor).max(50),
            big_threshold: (1000 / divisor).max(20),
            seed: 0x5EC_F1D1C,
            engine: EngineConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Scale-dependent defaults for a given divisor.
    pub fn for_divisor(divisor: usize) -> Self {
        Self {
            divisor,
            theta: (25_000 / divisor).max(50),
            big_threshold: (1000 / divisor).max(20),
            ..Default::default()
        }
    }

    /// Generate + preprocess one scale point, `Arc`-shared so sessions and
    /// reports can reference the data without copying it.
    pub fn build_scale(&self, replication: usize) -> (Arc<Trace>, Arc<Preprocessed>) {
        let (trace, g, splits) = generate(&GeneratorConfig {
            seed: self.seed,
            scale_divisor: self.divisor,
            replication,
            ..Default::default()
        });
        let pre = preprocess(&trace, &g, &splits, self.theta, self.big_threshold, WccImpl::Driver);
        (Arc::new(trace), Arc::new(pre))
    }

    /// [`build_scale`](Self::build_scale) plus a ready [`ProvSession`] over
    /// the scale point.
    pub fn build_session(&self, replication: usize) -> Result<ProvSession> {
        let (trace, pre) = self.build_scale(replication);
        ProvSession::new(&self.engine, trace, pre)
    }
}

/// Table 9: weakly connected set statistics per (large component, split),
/// plus the set / set-dependency totals.
pub fn table9(pre: &Preprocessed) -> Table {
    let mut t = Table::new(
        "Table 9 — Weakly Connected Sets Statistics (sets, ≥big, largest)",
        &["Component", "Split", "# sets", "# big sets", "largest (nodes)"],
    );
    for p in &pre.pass_stats {
        t.row(vec![
            p.component.clone(),
            p.split.clone(),
            p.sets.to_string(),
            p.big_sets.to_string(),
            p.largest.to_string(),
        ]);
    }
    t.row(vec![
        "TOTAL".into(),
        "-".into(),
        pre.set_count.to_string(),
        "-".into(),
        format!("set-deps = {}", pre.set_deps.len()),
    ]);
    t
}

/// Tables 10–12: average query latency per engine across scales, for one
/// query class. Returns the table plus the raw seconds for EXPERIMENTS.md.
pub fn query_table(
    class: QueryClass,
    cfg: &ExperimentConfig,
) -> Result<(Table, Vec<(String, f64, f64, f64)>)> {
    let title = match class {
        QueryClass::ScSl => "Table 10 — Class SC-SL (avg query latency)",
        QueryClass::LcSl => "Table 11 — Class LC-SL (avg query latency)",
        QueryClass::LcLl => "Table 12 — Class LC-LL (avg query latency)",
    };
    let mut t = Table::new(title, &["Scale", "elements", "RQ", "CCProv", "CSProv"]);
    let mut raw = Vec::new();

    for &rep in &cfg.replications {
        let session = cfg.build_session(rep)?;
        let (trace, pre) = (session.trace(), session.pre());
        let elements = trace.len() + pre.cc_of.len();
        let sel =
            select_queries(&trace, &pre, class, cfg.queries_per_class, cfg.divisor, cfg.seed)?;

        let avg = |router: EngineRouter| -> f64 {
            let t0 = Instant::now();
            for &q in &sel.items {
                let _ = session.execute_on(router, &QueryRequest::new(q));
            }
            t0.elapsed().as_secs_f64() / sel.items.len() as f64
        };
        let rq_s = avg(EngineRouter::Rq);
        let cc_s = avg(EngineRouter::CcProv);
        let cs_s = avg(EngineRouter::CsProv);

        let label = format!("×{rep}");
        t.row(vec![
            label.clone(),
            human_count(elements as u64),
            human_duration(std::time::Duration::from_secs_f64(rq_s)),
            human_duration(std::time::Duration::from_secs_f64(cc_s)),
            human_duration(std::time::Duration::from_secs_f64(cs_s)),
        ]);
        raw.push((label, rq_s, cc_s, cs_s));
    }
    Ok((t, raw))
}

/// §4-Discussion drill-down for one query: set, set-lineage size, and the
/// minimal volume CSProv recurses over vs. what CCProv / RQ would process.
pub fn drilldown_report(session: &ProvSession, q: u64) -> String {
    // One epoch snapshot for the whole report — trace, index, and engines
    // must describe the same ingestion state even if a concurrent ingest
    // swaps epochs mid-report.
    let engines = session.engines();
    let trace = engines.trace();
    let pre = engines.pre();
    let cc = pre.cc_of.get(&q).copied();
    let cs = pre.cs_of.get(&q).copied();
    let mut out = String::new();
    out.push_str(&format!("query item      : {q} ({})\n", crate::util::ids::AttrValueId(q)));
    let (Some(cc), Some(cs)) = (cc, cs) else {
        out.push_str("item unknown to the trace\n");
        return out;
    };
    let comp_edges = trace.triples.iter().filter(|t| pre.cc_of[&t.src.raw()] == cc).count();
    let set_lineage = engines.csprov.set_lineage(cs);
    let volume = engines.csprov.lineage_volume(q);
    let resp = engines.route(EngineRouter::CsProv, q).execute(&QueryRequest::new(q));
    let lineage = &resp.lineage;
    out.push_str(&format!("component       : {cc} ({} triples)\n", human_count(comp_edges as u64)));
    out.push_str(&format!("connected set   : {cs}\n"));
    out.push_str(&format!("set-lineage     : {} sets\n", set_lineage.len()));
    out.push_str(&format!(
        "CSProv recurses : {} triples (CCProv: {}, RQ: {})\n",
        human_count(volume as u64),
        human_count(comp_edges as u64),
        human_count(trace.len() as u64),
    ));
    out.push_str(&format!(
        "lineage         : {} ancestors, {} triples, {} transformations\n",
        lineage.ancestors.len(),
        lineage.triples.len(),
        lineage.transformation_count(),
    ));
    out.push_str(&format!("query stats     : {}\n", resp.stats.summary()));
    out
}

/// Component-size census used by `provspark stats` and the EXPERIMENTS.md
/// trace-statistics section.
pub fn component_census(pre: &Preprocessed) -> Table {
    let mut sizes: FxHashMap<u64, usize> = FxHashMap::default();
    for &cc in pre.cc_of.values() {
        *sizes.entry(cc).or_default() += 1;
    }
    let mut buckets = [0usize; 4]; // ≤20, 21..big, big..θ, large
    let large: rustc_hash::FxHashSet<u64> =
        pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
    for (&cc, &n) in &sizes {
        if large.contains(&cc) {
            buckets[3] += 1;
        } else if n <= 20 {
            buckets[0] += 1;
        } else if n <= 900 {
            buckets[1] += 1;
        } else {
            buckets[2] += 1;
        }
    }
    let mut t = Table::new("Component census", &["bucket", "count"]);
    t.row(vec!["small (≤20 nodes)".into(), buckets[0].to_string()]);
    t.row(vec!["21–900 nodes".into(), buckets[1].to_string()]);
    t.row(vec!["mid (>900, below θ)".into(), buckets[2].to_string()]);
    t.row(vec!["large (≥θ, partitioned)".into(), buckets[3].to_string()]);
    t.row(vec!["TOTAL components".into(), pre.component_count.to_string()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::for_divisor(1000);
        cfg.replications = vec![1, 2];
        cfg.queries_per_class = 3;
        cfg.theta = 300;
        cfg.big_threshold = 100;
        cfg.engine.cluster.job_overhead_us = 0;
        cfg
    }

    #[test]
    fn table9_renders() {
        let cfg = tiny_cfg();
        let (_, pre) = cfg.build_scale(1);
        let t = table9(&pre);
        let r = t.render();
        assert!(r.contains("LC1"));
        assert!(r.contains("set-deps"));
    }

    #[test]
    fn query_table_has_row_per_scale() {
        let cfg = tiny_cfg();
        let (t, raw) = query_table(QueryClass::ScSl, &cfg).unwrap();
        assert_eq!(raw.len(), 2);
        assert!(t.render().contains("×2"));
    }

    #[test]
    fn drilldown_mentions_volumes() {
        let cfg = tiny_cfg();
        let session = cfg.build_session(1).unwrap();
        let sel =
            select_queries(&session.trace(), &session.pre(), QueryClass::LcSl, 1, 1000, 1)
                .unwrap();
        let report = drilldown_report(&session, sel.items[0]);
        assert!(report.contains("CSProv recurses"), "{report}");
        assert!(report.contains("query stats"), "{report}");
    }

    #[test]
    fn census_counts_everything() {
        let cfg = tiny_cfg();
        let (_, pre) = cfg.build_scale(1);
        let t = component_census(&pre);
        assert!(t.render().contains("TOTAL components"));
    }
}
