//! Engine assembly: build the three query engines from one preprocessed
//! trace, with the configured τ and closure backend.

use crate::config::{Backend, EngineConfig};
use crate::minispark::MiniSpark;
use crate::provenance::model::Trace;
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
use crate::provenance::query::{CcProvEngine, CsProvEngine, RqEngine};
use crate::runtime::{XlaClosure, XlaRuntime};
use anyhow::Result;
use std::sync::Arc;

/// All three engines over one dataset.
pub struct EngineSet {
    pub rq: RqEngine,
    pub ccprov: CcProvEngine,
    pub csprov: CsProvEngine,
}

/// Resolve the configured closure backend (XLA requires artifacts; errors
/// surface at build time, not query time).
pub fn make_closure(cfg: &EngineConfig) -> Result<Arc<dyn AncestorClosure>> {
    Ok(match cfg.prov.closure_backend {
        Backend::Native => Arc::new(NativeClosure),
        Backend::Xla => {
            let rt = XlaRuntime::new(std::path::Path::new(&cfg.prov.artifact_dir))?;
            Arc::new(XlaClosure::new(Arc::new(rt)))
        }
    })
}

impl EngineSet {
    /// Build RQ + CCProv + CSProv from a preprocessed trace.
    pub fn build(
        sc: &MiniSpark,
        trace: &Trace,
        pre: &Preprocessed,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        let np = cfg.cluster.default_partitions;
        let tau = cfg.prov.tau;
        let closure = make_closure(cfg)?;
        let rq = RqEngine::new(sc, trace, np);
        let ccprov = CcProvEngine::new(sc, pre.cc_triples.clone(), np, tau)
            .with_closure(Arc::clone(&closure));
        let csprov = CsProvEngine::new(
            sc,
            pre.cs_triples.clone(),
            pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect(),
            pre.set_deps.clone(),
            np,
            tau,
        )
        .with_closure(closure);
        Ok(Self { rq, ccprov, csprov })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    #[test]
    fn engine_set_builds_and_agrees() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = 50;
        let sc = MiniSpark::new(cfg.cluster.clone());
        let set = EngineSet::build(&sc, &trace, &pre, &cfg).unwrap();
        let q = trace.triples[trace.len() / 3].dst.raw();
        let a = set.rq.query(q);
        assert_eq!(set.ccprov.query(q), a);
        assert_eq!(set.csprov.query(q), a);
    }
}
