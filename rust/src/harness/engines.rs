//! Engine assembly: build the three query engines from one preprocessed
//! trace, with the configured τ and closure backend.
//!
//! [`EngineSet::build`] takes the trace and preprocessed data behind `Arc`s
//! and hands the engine builders borrowed slices, which they partition in a
//! single pass — no wholesale `Vec` clones anywhere on the construction
//! path. The `(node, csid)` index CSProv resolves items against is derived
//! here exactly once per set.

use crate::config::{Backend, EngineConfig};
use crate::minispark::MiniSpark;
use crate::provenance::model::Trace;
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
use crate::provenance::query::{CcProvEngine, CsProvEngine, ProvenanceEngine, RqEngine};
use crate::runtime::{XlaClosure, XlaRuntime};
use anyhow::Result;
use std::sync::Arc;

/// All three engines over one dataset, sharing the source data by `Arc`.
pub struct EngineSet {
    trace: Arc<Trace>,
    pre: Arc<Preprocessed>,
    pub rq: RqEngine,
    pub ccprov: CcProvEngine,
    pub csprov: CsProvEngine,
}

/// Resolve the configured closure backend (XLA requires artifacts; errors
/// surface at build time, not query time).
pub fn make_closure(cfg: &EngineConfig) -> Result<Arc<dyn AncestorClosure>> {
    Ok(match cfg.prov.closure_backend {
        Backend::Native => Arc::new(NativeClosure),
        Backend::Xla => {
            let rt = XlaRuntime::new(std::path::Path::new(&cfg.prov.artifact_dir))?;
            Arc::new(XlaClosure::new(Arc::new(rt)))
        }
    })
}

impl EngineSet {
    /// Build RQ + CCProv + CSProv from a preprocessed trace. The set keeps
    /// the `Arc`s alive for its engines and for callers needing the source
    /// data ([`trace`](Self::trace) / [`pre`](Self::pre)).
    pub fn build(
        sc: &MiniSpark,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        let np = cfg.cluster.default_partitions;
        let tau = cfg.prov.tau;
        let closure = make_closure(cfg)?;
        let rq = RqEngine::new(sc, &trace.triples, np);
        let ccprov =
            CcProvEngine::new(sc, &pre.cc_triples, np, tau).with_closure(Arc::clone(&closure));
        // The (node, csid) index is derived from `cs_of` once, here.
        let node_set: Vec<(u64, u64)> = pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect();
        let csprov = CsProvEngine::new(sc, &pre.cs_triples, node_set, &pre.set_deps, np, tau)
            .with_closure(closure);
        Ok(Self { trace, pre, rq, ccprov, csprov })
    }

    /// The source trace the engines were built from.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// The preprocessed data the engines were built from.
    pub fn pre(&self) -> &Arc<Preprocessed> {
        &self.pre
    }

    /// The engines as trait objects, in `(name, engine)` pairs — what the
    /// cross-engine equivalence tests and session routing iterate over.
    pub fn as_dyn(&self) -> [(&'static str, &dyn ProvenanceEngine); 3] {
        [
            (self.rq.name(), &self.rq),
            (self.ccprov.name(), &self.ccprov),
            (self.csprov.name(), &self.csprov),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::provenance::query::QueryRequest;
    use crate::workflow::generator::{generate, GeneratorConfig};

    #[test]
    fn engine_set_builds_and_agrees() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = 50;
        let sc = MiniSpark::new(cfg.cluster.clone());
        let trace = Arc::new(trace);
        let set = EngineSet::build(&sc, Arc::clone(&trace), Arc::new(pre), &cfg).unwrap();
        let q = trace.triples[trace.len() / 3].dst.raw();
        let a = set.rq.query(q);
        assert_eq!(set.ccprov.query(q), a);
        assert_eq!(set.csprov.query(q), a);
        // Trait objects answer the same request identically.
        for (name, engine) in set.as_dyn() {
            let resp = engine.execute(&QueryRequest::new(q));
            assert_eq!(resp.lineage, a, "{name}");
            assert_eq!(resp.stats.engine, name);
        }
    }
}
