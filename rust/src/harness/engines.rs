//! Engine assembly: build the three query engines from one preprocessed
//! trace, with the configured τ and closure backend — and keep them live
//! across incremental-ingestion epochs.
//!
//! [`EngineSet::build`] takes the trace and preprocessed data behind `Arc`s
//! and hands the engine builders borrowed slices, which they partition in a
//! single pass — no wholesale `Vec` clones anywhere on the construction
//! path. The `(node, csid)` index CSProv resolves items against is derived
//! here exactly once per set.
//!
//! [`EngineSet::absorb`] is the delta path: given the previous epoch's
//! engines and the [`AppliedDelta`] an
//! [`IncrementalIndex`](crate::provenance::incremental::IncrementalIndex)
//! produced, it derives the next epoch's engines by routing appended rows
//! into the existing datasets and patching only the partitions whose rows
//! were retagged ([`Dataset::append_partitioned`] /
//! [`Dataset::patch_partitions`]) — never a full rebuild. Both paths hand
//! out engines whose hot-component / hot-set assemble memos (the lazy
//! planner's memoized stages; see `CcProvEngine::assemble`) start cold:
//! `with_delta` and `spilled` reset them, so an epoch never serves a
//! stale component and a spilled engine never pins pre-spill partitions.
//!
//! [`Dataset::append_partitioned`]: crate::minispark::Dataset::append_partitioned
//! [`Dataset::patch_partitions`]: crate::minispark::Dataset::patch_partitions

use super::session::EngineRouter;
use crate::config::{Backend, EngineConfig};
use crate::minispark::{Dataset, KeyTag, MiniSpark};
use crate::provenance::incremental::AppliedDelta;
use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
use crate::provenance::query::{
    CcProvEngine, CsDelta, CsProvEngine, ProvenanceEngine, RqEngine, KEY_DST_CSID, KEY_TRIPLE_DST,
};
use crate::provenance::store::SegmentedPre;
use crate::runtime::{XlaClosure, XlaRuntime};
use crate::storage::SegmentCodec;
use crate::util::ids::ComponentId;
use anyhow::{ensure, Result};
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::Arc;

/// All three engines over one dataset epoch, sharing the source data by
/// `Arc`. One `EngineSet` is immutable; ingestion produces the *next* set
/// via [`absorb`](Self::absorb) (see `ProvSession` for the epoch swap).
pub struct EngineSet {
    trace: Arc<Trace>,
    pre: Arc<Preprocessed>,
    /// Component ids that were Algorithm 3-partitioned (the `Auto` key).
    large: FxHashSet<u64>,
    pub rq: RqEngine,
    pub ccprov: CcProvEngine,
    pub csprov: CsProvEngine,
}

/// Resolve the configured closure backend (XLA requires artifacts; errors
/// surface at build time, not query time).
pub fn make_closure(cfg: &EngineConfig) -> Result<Arc<dyn AncestorClosure>> {
    Ok(match cfg.prov.closure_backend {
        Backend::Native => Arc::new(NativeClosure),
        Backend::Xla => {
            let rt = XlaRuntime::new(std::path::Path::new(&cfg.prov.artifact_dir))?;
            Arc::new(XlaClosure::new(Arc::new(rt)))
        }
    })
}

impl EngineSet {
    /// Build RQ + CCProv + CSProv from a preprocessed trace. The set keeps
    /// the `Arc`s alive for its engines and for callers needing the source
    /// data ([`trace`](Self::trace) / [`pre`](Self::pre)).
    pub fn build(
        sc: &MiniSpark,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        let np = cfg.cluster.default_partitions;
        let tau = cfg.prov.tau;
        let closure = make_closure(cfg)?;
        // `spilled()` writes each engine's datasets to segment files when
        // the context carries a memory budget (demand-paged thereafter),
        // and is a no-op clone when it doesn't.
        let rq = RqEngine::new(sc, &trace.triples, np).spilled()?;
        let ccprov = CcProvEngine::new(sc, &pre.cc_triples, np, tau)
            .with_closure(Arc::clone(&closure))
            .spilled()?;
        // The (node, csid) index is derived from `cs_of` once, here.
        let node_set: Vec<(u64, u64)> = pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect();
        let csprov = CsProvEngine::new(sc, &pre.cs_triples, node_set, &pre.set_deps, np, tau)
            .with_closure(closure)
            .spilled()?;
        let large = large_of(&pre);
        Ok(Self { trace, pre, large, rq, ccprov, csprov })
    }

    /// Zero-copy cold start: build the engines directly over an open
    /// [`SegmentedPre`], demand-loading triple partitions straight into
    /// paged datasets instead of load-whole-then-re-spill. Opening a
    /// session this way reads only the store's header-adjacent sections
    /// (node/component maps, set dependencies, large-component summaries);
    /// the two triple sections stay on disk until a query faults — or a
    /// frontier prefetch warms — their partitions.
    ///
    /// Every paged load charges the engine ledger: `bytes_paged_in` counts
    /// the on-disk (v5: compressed) bytes, `bytes_decoded` the decoded
    /// rows, and `bytes_compressed` the savings against the raw v4 record
    /// encoding (zero for an uncompressed v4 source).
    ///
    /// Falls back to [`build`](Self::build) (full load, then re-spill
    /// under a budget) when the file's partition count differs from the
    /// configured one — the paged partitions must *be* the engines'
    /// partitions for lookups to prune.
    pub fn build_from_segments(
        sc: &MiniSpark,
        trace: Arc<Trace>,
        seg: Arc<SegmentedPre>,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        let np = cfg.cluster.default_partitions;
        if seg.num_partitions() != np {
            return Self::build(sc, trace, Arc::new(seg.load_all()?), cfg);
        }
        let tau = cfg.prov.tau;
        let closure = make_closure(cfg)?;
        // Everything except the triple sections, loaded eagerly (small).
        let pre = Arc::new(seg.load_light()?);
        let cc_rows: Vec<usize> = (0..np).map(|i| seg.cc_rows(i)).collect();
        let cs_rows: Vec<usize> = (0..np).map(|i| seg.cs_rows(i)).collect();

        // RQ pages the cc sections too (same dst keying and partition
        // count), stripping the component tag as rows decode.
        let rq_ds = {
            let (seg, scc) = (Arc::clone(&seg), sc.clone());
            Dataset::from_paged_store(
                sc,
                &cc_rows,
                KEY_TRIPLE_DST,
                |t: &ProvTriple| t.dst.raw(),
                move |i| {
                    let rows = seg.cc_partition(i as usize)?;
                    let disk = seg.cc_bytes(i as usize);
                    scc.metrics().add_bytes_compressed(
                        (rows.len() as u64 * CcTriple::RECORD_BYTES as u64).saturating_sub(disk),
                    );
                    Ok((rows.into_iter().map(|t| t.triple).collect(), disk))
                },
            )
        };
        let rq = RqEngine::from_dataset(rq_ds);

        let cc_ds = {
            let (seg, scc) = (Arc::clone(&seg), sc.clone());
            Dataset::from_paged_store(
                sc,
                &cc_rows,
                KEY_TRIPLE_DST,
                |t: &CcTriple| t.triple.dst.raw(),
                move |i| {
                    let rows = seg.cc_partition(i as usize)?;
                    let disk = seg.cc_bytes(i as usize);
                    scc.metrics().add_bytes_compressed(
                        (rows.len() as u64 * CcTriple::RECORD_BYTES as u64).saturating_sub(disk),
                    );
                    Ok((rows, disk))
                },
            )
        };
        let ccprov = CcProvEngine::from_dataset(cc_ds, tau).with_closure(Arc::clone(&closure));

        let cs_ds = {
            let (seg, scc) = (Arc::clone(&seg), sc.clone());
            Dataset::from_paged_store(
                sc,
                &cs_rows,
                KEY_DST_CSID,
                |t: &CsTriple| t.dst_csid.0,
                move |i| {
                    let rows = seg.cs_partition(i as usize)?;
                    let disk = seg.cs_bytes(i as usize);
                    scc.metrics().add_bytes_compressed(
                        (rows.len() as u64 * CsTriple::RECORD_BYTES as u64).saturating_sub(disk),
                    );
                    Ok((rows, disk))
                },
            )
        };
        // The node index and set dependencies are small: build them from
        // the light load and spill them normally (no-op without a budget).
        let node_rows: Vec<(u64, u64)> = pre.cs_of.iter().map(|(&n, &c)| (n, c)).collect();
        let node_set = Dataset::hash_partitioned_from_slice(
            sc,
            &node_rows,
            np,
            KeyTag::PAIR_KEY,
            |r: &(u64, u64)| r.0,
        )
        .spilled("cs-nodeset")?;
        let set_deps = Dataset::hash_partitioned_from_slice(
            sc,
            &pre.set_deps,
            np,
            KEY_DST_CSID,
            |d: &SetDep| d.dst_csid.0,
        )
        .spilled("cs-setdeps")?;
        let csprov =
            CsProvEngine::from_datasets(cs_ds, node_set, set_deps, np, tau).with_closure(closure);

        let large = large_of(&pre);
        Ok(Self { trace, pre, large, rq, ccprov, csprov })
    }

    /// Derive the next epoch's engines from the previous epoch plus an
    /// [`AppliedDelta`]: appended rows are routed into the existing
    /// partitions, retagged rows are dropped/patched only where they live,
    /// and the `(node, csid)` / set-dependency indexes absorb their diffs.
    /// τ and the closure backend carry over from `prev`.
    ///
    /// `trace` / `pre` must be the post-apply snapshot the delta describes
    /// (`IncrementalIndex::snapshot`).
    pub fn absorb(
        prev: &EngineSet,
        trace: Arc<Trace>,
        pre: Arc<Preprocessed>,
        delta: &AppliedDelta,
    ) -> Result<Self> {
        ensure!(
            pre.cc_triples.len() == trace.len() && pre.cs_triples.len() == trace.len(),
            "snapshot mismatch: {} triples vs {} cc / {} cs rows",
            trace.len(),
            pre.cc_triples.len(),
            pre.cs_triples.len(),
        );
        ensure!(
            delta.first_new_triple == prev.trace.len()
                && trace.len() == prev.trace.len() + delta.stats.new_triples,
            "delta does not extend the previous epoch (prev {} rows, delta starts at {})",
            prev.trace.len(),
            delta.first_new_triple,
        );
        let first = delta.first_new_triple;

        // Absorption leaves the touched partitions resident; a budgeted
        // context re-spills each engine so the next epoch is fully paged
        // again (no-op without a budget).
        let rq = prev.rq.with_appended(&trace.triples[first..]).spilled()?;

        // CCProv: dst keys never change, so retagging is an in-place patch.
        let mut retag_cc: FxHashMap<ProvTriple, ComponentId> = FxHashMap::default();
        for &i in &delta.retag_cc {
            let row = pre.cc_triples[i as usize];
            retag_cc.insert(row.triple, row.ccid);
        }
        let ccprov = prev.ccprov.with_delta(&retag_cc, &pre.cc_triples[first..]).spilled()?;

        // CSProv: dst_csid (the partitioning key) can change, so retagged
        // rows are dropped from their old partitions and re-routed.
        let mut retag_cs: FxHashMap<ProvTriple, crate::provenance::model::CsTriple> =
            FxHashMap::default();
        let mut old_keys: FxHashSet<u64> = FxHashSet::default();
        let mut rerouted = Vec::with_capacity(delta.retag_cs.len());
        for &(i, old) in &delta.retag_cs {
            let new_row = pre.cs_triples[i as usize];
            retag_cs.insert(old.triple, new_row);
            old_keys.insert(old.dst_csid.0);
            rerouted.push(new_row);
        }
        let old_keys: Vec<u64> = old_keys.into_iter().collect();
        let node_patch: FxHashMap<u64, u64> = delta.node_changes.iter().copied().collect();
        let removed_deps: FxHashSet<SetDep> = delta.removed_deps.iter().copied().collect();
        let removed_dep_keys: Vec<u64> = removed_deps
            .iter()
            .map(|d| d.dst_csid.0)
            .collect::<FxHashSet<u64>>()
            .into_iter()
            .collect();
        let csprov = prev.csprov.with_delta(&CsDelta {
            retagged: &retag_cs,
            old_keys: &old_keys,
            rerouted: &rerouted,
            appended: &pre.cs_triples[first..],
            node_patch: &node_patch,
            new_nodes: &delta.new_nodes,
            removed_deps: &removed_deps,
            removed_dep_keys: &removed_dep_keys,
            added_deps: &delta.added_deps,
        });
        let csprov = csprov.spilled()?;

        let large = large_of(&pre);
        Ok(Self { trace, pre, large, rq, ccprov, csprov })
    }

    /// The source trace the engines were built from.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// The preprocessed data the engines were built from.
    pub fn pre(&self) -> &Arc<Preprocessed> {
        &self.pre
    }

    /// Resolve a routing policy for one item to a concrete engine.
    ///
    /// `Auto` routes on data shape: items in a *large* (Algorithm
    /// 3-partitioned) component go to CSProv, whose set-lineage pruning is
    /// what makes those queries real-time; items in small components go to
    /// CCProv (their component is a single set, so CSProv would reduce to
    /// CCProv anyway, §2.3); unknown items go to CSProv, whose node-index
    /// miss is the cheapest rejection. `Auto` never picks RQ — the baseline
    /// exists to be measured against, not to serve traffic.
    pub fn route(&self, router: EngineRouter, item: u64) -> &dyn ProvenanceEngine {
        match router {
            EngineRouter::Rq => &self.rq,
            EngineRouter::CcProv => &self.ccprov,
            EngineRouter::CsProv => &self.csprov,
            EngineRouter::Auto => match self.pre.cc_of.get(&item) {
                Some(cc) if self.large.contains(cc) => &self.csprov,
                Some(_) => &self.ccprov,
                None => &self.csprov,
            },
        }
    }

    /// The engines as trait objects, in `(name, engine)` pairs — what the
    /// cross-engine equivalence tests and session routing iterate over.
    pub fn as_dyn(&self) -> [(&'static str, &dyn ProvenanceEngine); 3] {
        [
            (self.rq.name(), &self.rq),
            (self.ccprov.name(), &self.ccprov),
            (self.csprov.name(), &self.csprov),
        ]
    }
}

fn large_of(pre: &Preprocessed) -> FxHashSet<u64> {
    pre.large_components.iter().map(|&(cc, _, _)| cc).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::incremental::{IncrementalIndex, TripleBatch};
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::provenance::query::QueryRequest;
    use crate::workflow::generator::{generate, GeneratorConfig};

    #[test]
    fn engine_set_builds_and_agrees() {
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 200, 100, WccImpl::Driver);
        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = 50;
        let sc = MiniSpark::new(cfg.cluster.clone());
        let trace = Arc::new(trace);
        let set = EngineSet::build(&sc, Arc::clone(&trace), Arc::new(pre), &cfg).unwrap();
        let q = trace.triples[trace.len() / 3].dst.raw();
        let a = set.rq.query(q);
        assert_eq!(set.ccprov.query(q), a);
        assert_eq!(set.csprov.query(q), a);
        // Trait objects answer the same request identically.
        for (name, engine) in set.as_dyn() {
            let resp = engine.execute(&QueryRequest::new(q));
            assert_eq!(resp.lineage, a, "{name}");
            assert_eq!(resp.stats.engine, name);
        }
    }

    #[test]
    fn absorbed_engines_match_rebuilt_engines() {
        let (full, g, splits) =
            generate(&GeneratorConfig { scale_divisor: 2000, ..Default::default() });
        let cut = full.len() * 9 / 10;
        let base = Trace::new(full.triples[..cut].to_vec());
        let batch = TripleBatch::new(full.triples[cut..].to_vec());

        let mut cfg = EngineConfig::default();
        cfg.cluster.job_overhead_us = 0;
        cfg.prov.tau = 200;
        let sc = MiniSpark::new(cfg.cluster.clone());

        let base_pre = preprocess(&base, &g, &splits, 150, 100, WccImpl::Driver);
        let mut idx =
            IncrementalIndex::new(base.clone(), base_pre.clone(), g, splits).unwrap();
        let prev =
            EngineSet::build(&sc, Arc::new(base), Arc::new(base_pre), &cfg).unwrap();
        let delta = idx.apply(&batch).unwrap();
        let (trace, pre) = idx.snapshot();
        let absorbed = EngineSet::absorb(&prev, trace, Arc::clone(&pre), &delta).unwrap();

        // Rebuild from the same snapshot and compare answers + routing.
        let (trace2, pre2) = idx.snapshot();
        let rebuilt = EngineSet::build(&sc, trace2, pre2, &cfg).unwrap();
        let mut items: Vec<u64> = absorbed
            .trace()
            .triples
            .iter()
            .step_by(absorbed.trace().len() / 14 + 1)
            .map(|t| t.dst.raw())
            .collect();
        items.push(u64::MAX - 3); // unknown
        for &q in &items {
            let req = QueryRequest::new(q);
            for ((an, ae), (bn, be)) in absorbed.as_dyn().into_iter().zip(rebuilt.as_dyn())
            {
                assert_eq!(an, bn);
                assert_eq!(
                    ae.execute(&req).lineage,
                    be.execute(&req).lineage,
                    "{an} diverges for q={q}"
                );
            }
            assert_eq!(
                absorbed.route(EngineRouter::Auto, q).name(),
                rebuilt.route(EngineRouter::Auto, q).name(),
                "auto routing diverges for q={q}"
            );
        }
        // Absorption did not lose or duplicate rows.
        assert_eq!(absorbed.rq.dataset().len(), rebuilt.rq.dataset().len());
    }
}
