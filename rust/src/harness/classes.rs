//! Query-class selection (paper §4):
//!
//! * **SC-SL** — items in a *small* (largest non-large) component with a
//!   small lineage;
//! * **LC-SL** — items in the largest component LC1, small lineage;
//! * **LC-LL** — items in LC1, large lineage.
//!
//! The paper's absolute bands (100–200 / 5000–10000 ancestors) refer to
//! the full-fidelity trace; at a scale divisor `d` the bands shrink by
//! `d` with sane floors. Selection is adaptive: if a band yields fewer
//! than the requested items, it widens geometrically (and reports the band
//! actually used) so the classes remain meaningful at any scale.

use crate::provenance::model::{ProvTriple, Trace};
use crate::provenance::pipeline::Preprocessed;
use crate::provenance::query::driver_rq::{AncestorClosure, NativeClosure};
use crate::util::rng::Pcg64;
use rustc_hash::FxHashMap;

/// The three query classes of §4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryClass {
    ScSl,
    LcSl,
    LcLl,
}

impl std::str::FromStr for QueryClass {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sc-sl" | "scsl" => Ok(QueryClass::ScSl),
            "lc-sl" | "lcsl" => Ok(QueryClass::LcSl),
            "lc-ll" | "lcll" => Ok(QueryClass::LcLl),
            other => anyhow::bail!("unknown query class {other:?} (sc-sl|lc-sl|lc-ll)"),
        }
    }
}

impl std::fmt::Display for QueryClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QueryClass::ScSl => "SC-SL",
            QueryClass::LcSl => "LC-SL",
            QueryClass::LcLl => "LC-LL",
        })
    }
}

impl QueryClass {
    /// Ancestor-count band at the given scale divisor (paper bands ÷ d,
    /// floored so the classes stay distinguishable at small scales).
    pub fn band(&self, divisor: usize) -> (usize, usize) {
        let d = divisor.max(1);
        match self {
            QueryClass::ScSl | QueryClass::LcSl => ((100 / d).max(5), (200 / d).max(12)),
            QueryClass::LcLl => ((5000 / d).max(60), (10_000 / d).max(150)),
        }
    }
}

/// Outcome of a selection: the items plus the band that produced them.
#[derive(Debug, Clone)]
pub struct SelectedQueries {
    pub class: QueryClass,
    pub items: Vec<u64>,
    pub band: (usize, usize),
    /// Component the items were drawn from.
    pub component: u64,
}

/// Pick `count` query items of the given class (paper uses 10 per class).
pub fn select_queries(
    trace: &Trace,
    pre: &Preprocessed,
    class: QueryClass,
    count: usize,
    divisor: usize,
    seed: u64,
) -> anyhow::Result<SelectedQueries> {
    // Target component: LC1 for the LC classes; the largest *small*
    // component for SC-SL (the paper queries a 7453-node component).
    let target_cc = match class {
        QueryClass::LcSl | QueryClass::LcLl => {
            pre.large_components
                .first()
                .ok_or_else(|| anyhow::anyhow!("no large components in this trace"))?
                .0
        }
        QueryClass::ScSl => {
            let large: rustc_hash::FxHashSet<u64> =
                pre.large_components.iter().map(|&(cc, _, _)| cc).collect();
            let mut sizes: FxHashMap<u64, usize> = FxHashMap::default();
            for &cc in pre.cc_of.values() {
                if !large.contains(&cc) {
                    *sizes.entry(cc).or_default() += 1;
                }
            }
            *sizes
                .iter()
                .max_by_key(|&(_, &n)| n)
                .ok_or_else(|| anyhow::anyhow!("no small components"))?
                .0
        }
    };

    // Component triples (single scan) and candidate derived items.
    let comp_triples: Vec<ProvTriple> = trace
        .triples
        .iter()
        .filter(|t| pre.cc_of[&t.src.raw()] == target_cc)
        .copied()
        .collect();
    let mut candidates: Vec<u64> = comp_triples.iter().map(|t| t.dst.raw()).collect();
    candidates.sort_unstable();
    candidates.dedup();
    let mut rng = Pcg64::new(seed);
    rng.shuffle(&mut candidates);

    // Adaptive band widening.
    let (mut lo, mut hi) = class.band(divisor);
    loop {
        let mut items = Vec::with_capacity(count);
        for &q in candidates.iter().take(6000) {
            let anc = NativeClosure.closure(&comp_triples, q).ancestors.len();
            if anc >= lo && anc <= hi {
                items.push(q);
                if items.len() == count {
                    break;
                }
            }
        }
        if items.len() >= count.min(candidates.len()).max(1) || lo <= 1 {
            anyhow::ensure!(
                !items.is_empty(),
                "no items with ancestors in [{lo}, {hi}] in component {target_cc}"
            );
            return Ok(SelectedQueries { class, items, band: (lo, hi), component: target_cc });
        }
        lo = (lo / 2).max(1);
        hi *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::pipeline::{preprocess, WccImpl};
    use crate::workflow::generator::{generate, GeneratorConfig};

    #[test]
    fn class_parsing_and_bands() {
        assert_eq!("sc-sl".parse::<QueryClass>().unwrap(), QueryClass::ScSl);
        assert_eq!("LC-LL".parse::<QueryClass>().unwrap(), QueryClass::LcLl);
        assert!("xx".parse::<QueryClass>().is_err());
        let (lo, hi) = QueryClass::LcLl.band(1);
        assert_eq!((lo, hi), (5000, 10_000));
        let (lo, hi) = QueryClass::ScSl.band(10);
        assert_eq!((lo, hi), (10, 20));
    }

    #[test]
    fn selects_items_for_all_classes() {
        let div = 500;
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: div, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 600, 100, WccImpl::Driver);
        for class in [QueryClass::ScSl, QueryClass::LcSl, QueryClass::LcLl] {
            let sel = select_queries(&trace, &pre, class, 5, div, 42).unwrap();
            assert!(!sel.items.is_empty(), "{class}: no items");
            // LC classes draw from LC1; SC-SL from elsewhere.
            let lc1 = pre.large_components[0].0;
            for &q in &sel.items {
                let cc = pre.cc_of[&q];
                match class {
                    QueryClass::ScSl => assert_ne!(cc, lc1),
                    _ => assert_eq!(cc, lc1),
                }
            }
        }
    }

    #[test]
    fn selection_is_deterministic() {
        let div = 1000;
        let (trace, g, splits) =
            generate(&GeneratorConfig { scale_divisor: div, ..Default::default() });
        let pre = preprocess(&trace, &g, &splits, 300, 100, WccImpl::Driver);
        let a = select_queries(&trace, &pre, QueryClass::LcSl, 4, div, 7).unwrap();
        let b = select_queries(&trace, &pre, QueryClass::LcSl, 4, div, 7).unwrap();
        assert_eq!(a.items, b.items);
    }
}
