//! Experiment harness: query-class selection (§4 "Provenance Queries"),
//! engine assembly ([`EngineSet`], including delta absorption across
//! ingestion epochs), the [`ProvSession`] query service (routing, batched
//! execution, live [`ProvSession::ingest`]), the [`ShardedSession`]
//! scatter-gather front over component-space shards, and the drivers that
//! regenerate every table of the paper's evaluation (Tables 9–12 plus the
//! Discussion drill-downs).

pub mod classes;
pub mod engines;
pub mod experiments;
pub mod session;
pub mod sharded;

pub use classes::{select_queries, QueryClass};
pub use engines::EngineSet;
pub use experiments::{
    component_census, drilldown_report, query_table, table9, ExperimentConfig,
};
pub use session::{EngineRouter, ProvSession};
pub use sharded::{
    ShardBatchStats, ShardedBatchReport, ShardedDeltaStats, ShardedSession, ShardRouter,
};
