//! The workflow dependency graph: which entity (table) is derived from
//! which — the paper's Figure 1 object. Algorithm 3 partitions it into
//! weakly connected *splits* to drive component partitioning.

use crate::util::ids::{EntityId, OpId};
use anyhow::{bail, Result};
use rustc_hash::{FxHashMap, FxHashSet};

/// Static description of one workflow entity (table).
#[derive(Debug, Clone)]
pub struct EntityInfo {
    pub id: EntityId,
    /// Short acronym, as in the paper's Figure 1.
    pub name: String,
    /// True for workflow inputs (the paper's `*`-marked entities).
    pub is_input: bool,
}

/// A directed edge `parent → child` ("child is derived from parent") plus
/// the transformation id that performs the derivation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DerivesEdge {
    pub parent: EntityId,
    pub child: EntityId,
    pub op: OpId,
}

/// The workflow dependency graph (a DAG over entities).
#[derive(Debug, Clone, Default)]
pub struct DependencyGraph {
    entities: Vec<EntityInfo>,
    edges: Vec<DerivesEdge>,
    by_name: FxHashMap<String, EntityId>,
}

impl DependencyGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an entity; returns its id. Names must be unique.
    pub fn add_entity(&mut self, name: &str, is_input: bool) -> EntityId {
        assert!(
            !self.by_name.contains_key(name),
            "duplicate entity name {name:?}"
        );
        let id = EntityId(self.entities.len() as u16);
        self.entities.push(EntityInfo { id, name: name.to_string(), is_input });
        self.by_name.insert(name.to_string(), id);
        id
    }

    /// Add a derivation edge `parent → child`; the transformation id is
    /// the edge's index (one transformation per table-to-table derivation).
    pub fn add_derivation(&mut self, parent: EntityId, child: EntityId) -> OpId {
        let op = OpId(self.edges.len() as u32);
        self.edges.push(DerivesEdge { parent, child, op });
        op
    }

    pub fn entities(&self) -> &[EntityInfo] {
        &self.entities
    }

    pub fn edges(&self) -> &[DerivesEdge] {
        &self.edges
    }

    pub fn entity_count(&self) -> usize {
        self.entities.len()
    }

    pub fn entity_by_name(&self, name: &str) -> Option<EntityId> {
        self.by_name.get(name).copied()
    }

    pub fn name_of(&self, e: EntityId) -> &str {
        &self.entities[e.0 as usize].name
    }

    /// Transformation id on the `parent → child` edge, if present.
    pub fn op_between(&self, parent: EntityId, child: EntityId) -> Option<OpId> {
        self.edges
            .iter()
            .find(|e| e.parent == parent && e.child == child)
            .map(|e| e.op)
    }

    /// Parent entities of `child`.
    pub fn parents_of(&self, child: EntityId) -> Vec<EntityId> {
        self.edges.iter().filter(|e| e.child == child).map(|e| e.parent).collect()
    }

    /// Child entities of `parent`.
    pub fn children_of(&self, parent: EntityId) -> Vec<EntityId> {
        self.edges.iter().filter(|e| e.parent == parent).map(|e| e.child).collect()
    }

    /// Entities in topological order (inputs first). Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<EntityId>> {
        let n = self.entities.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.child.0 as usize] += 1;
        }
        let mut queue: Vec<EntityId> = (0..n)
            .filter(|&i| indeg[i] == 0)
            .map(|i| EntityId(i as u16))
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(e) = queue.pop() {
            order.push(e);
            for c in self.children_of(e) {
                let d = &mut indeg[c.0 as usize];
                *d -= 1;
                if *d == 0 {
                    queue.push(c);
                }
            }
        }
        if order.len() != n {
            bail!("dependency graph has a cycle");
        }
        Ok(order)
    }

    /// Whether the given entity subset is weakly connected in this graph
    /// (Algorithm 3's key precondition on splits).
    pub fn is_weakly_connected(&self, subset: &[EntityId]) -> bool {
        if subset.is_empty() {
            return true;
        }
        let set: FxHashSet<EntityId> = subset.iter().copied().collect();
        let mut seen: FxHashSet<EntityId> = FxHashSet::default();
        let mut stack = vec![subset[0]];
        seen.insert(subset[0]);
        while let Some(e) = stack.pop() {
            for edge in &self.edges {
                let nbr = if edge.parent == e && set.contains(&edge.child) {
                    Some(edge.child)
                } else if edge.child == e && set.contains(&edge.parent) {
                    Some(edge.parent)
                } else {
                    None
                };
                if let Some(n) = nbr {
                    if seen.insert(n) {
                        stack.push(n);
                    }
                }
            }
        }
        seen.len() == set.len()
    }

    /// Undirected adjacency restricted to `subset` (entity → neighbours).
    pub fn undirected_adjacency(
        &self,
        subset: &[EntityId],
    ) -> FxHashMap<EntityId, Vec<EntityId>> {
        let set: FxHashSet<EntityId> = subset.iter().copied().collect();
        let mut adj: FxHashMap<EntityId, Vec<EntityId>> =
            subset.iter().map(|&e| (e, Vec::new())).collect();
        for e in &self.edges {
            if set.contains(&e.parent) && set.contains(&e.child) {
                adj.get_mut(&e.parent).unwrap().push(e.child);
                adj.get_mut(&e.child).unwrap().push(e.parent);
            }
        }
        adj
    }

    /// Graphviz DOT rendering (regenerates the paper's Figure 1 shape).
    pub fn to_dot(&self, split_of: impl Fn(EntityId) -> Option<String>) -> String {
        let mut out = String::from("digraph workflow {\n  rankdir=LR;\n");
        for e in &self.entities {
            let shape = if e.is_input { "box" } else { "ellipse" };
            let label = if e.is_input {
                format!("{}*", e.name)
            } else {
                e.name.clone()
            };
            let color = match split_of(e.id) {
                Some(sp) => format!(", colorscheme=set39, style=filled, fillcolor={}",
                    1 + (sp.bytes().map(|b| b as usize).sum::<usize>() % 9)),
                None => String::new(),
            };
            out.push_str(&format!(
                "  e{} [label=\"{}\", shape={}{}];\n",
                e.id.0, label, shape, color
            ));
        }
        for d in &self.edges {
            out.push_str(&format!("  e{} -> e{};\n", d.parent.0, d.child.0));
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DependencyGraph {
        let mut g = DependencyGraph::new();
        let a = g.add_entity("A", true);
        let b = g.add_entity("B", false);
        let c = g.add_entity("C", false);
        let d = g.add_entity("D", false);
        g.add_derivation(a, b);
        g.add_derivation(a, c);
        g.add_derivation(b, d);
        g.add_derivation(c, d);
        g
    }

    #[test]
    fn topo_order_valid() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: FxHashMap<EntityId, usize> =
            order.iter().enumerate().map(|(i, &e)| (e, i)).collect();
        for e in g.edges() {
            assert!(pos[&e.parent] < pos[&e.child]);
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = DependencyGraph::new();
        let a = g.add_entity("A", false);
        let b = g.add_entity("B", false);
        g.add_derivation(a, b);
        g.add_derivation(b, a);
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn weak_connectivity() {
        let g = diamond();
        let a = g.entity_by_name("A").unwrap();
        let b = g.entity_by_name("B").unwrap();
        let c = g.entity_by_name("C").unwrap();
        let d = g.entity_by_name("D").unwrap();
        assert!(g.is_weakly_connected(&[a, b, c, d]));
        assert!(g.is_weakly_connected(&[a, b]));
        assert!(g.is_weakly_connected(&[b, c, a])); // b-a-c semipath
        assert!(!g.is_weakly_connected(&[b, c])); // no direct link
        assert!(g.is_weakly_connected(&[]));
    }

    #[test]
    fn op_between_found() {
        let g = diamond();
        let a = g.entity_by_name("A").unwrap();
        let b = g.entity_by_name("B").unwrap();
        assert!(g.op_between(a, b).is_some());
        assert!(g.op_between(b, a).is_none());
    }

    #[test]
    fn dot_contains_entities() {
        let g = diamond();
        let dot = g.to_dot(|_| None);
        assert!(dot.contains("label=\"A*\""));
        assert!(dot.contains("e0 -> e1") || dot.contains("e0 -> e2"));
    }
}
