//! Splits: weakly connected subsets of the workflow dependency graph.
//!
//! Algorithm 3 partitions large provenance components by computing WCC on
//! the subgraph each split induces, and recurses with *sub-splits* when a
//! split-component is still too big. [`SplitSet`] carries the canonical
//! top-level splits plus named sub-split decompositions; when no explicit
//! decomposition exists, [`SplitSet::bisect`] derives one by removing the
//! most balanced spanning-tree edge of the split's induced entity graph —
//! both halves stay weakly connected by construction (the paper's key
//! constraint on splits).

use super::graph::DependencyGraph;
use crate::util::ids::EntityId;
use rustc_hash::{FxHashMap, FxHashSet};

/// A named, weakly connected subset of workflow entities.
#[derive(Debug, Clone)]
pub struct Split {
    name: String,
    entities: Vec<EntityId>,
}

impl Split {
    pub fn new(name: &str, entities: Vec<EntityId>) -> Self {
        assert!(!entities.is_empty(), "empty split {name}");
        Self { name: name.to_string(), entities }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn entities(&self) -> &[EntityId] {
        &self.entities
    }

    pub fn contains(&self, e: EntityId) -> bool {
        self.entities.contains(&e)
    }
}

/// The canonical split decomposition of a workflow.
#[derive(Debug, Clone)]
pub struct SplitSet {
    top: Vec<Split>,
    subs: FxHashMap<String, Vec<Split>>,
}

impl SplitSet {
    pub fn new(top: Vec<Split>, subs: Vec<(&str, Vec<Split>)>) -> Self {
        Self {
            top,
            subs: subs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        }
    }

    pub fn top_level(&self) -> &[Split] {
        &self.top
    }

    /// Explicit sub-splits registered for `name` (e.g. sp3 → [sp4, sp5]).
    pub fn sub_splits_of(&self, name: &str) -> Option<&[Split]> {
        self.subs.get(name).map(|v| v.as_slice())
    }

    /// Sub-splits for Algorithm 3's recursion: the registered decomposition
    /// if one exists, otherwise a computed bisection. Returns `None` when
    /// the split is a single entity (cannot be subdivided — Algorithm 3
    /// then keeps the oversized set as-is).
    pub fn get_sub_splits(&self, g: &DependencyGraph, sp: &Split) -> Option<Vec<Split>> {
        if let Some(subs) = self.sub_splits_of(sp.name()) {
            return Some(subs.to_vec());
        }
        bisect(g, sp)
    }

    /// Entity → top-level split name (used in reports and DOT output).
    pub fn split_of(&self, e: EntityId) -> Option<&str> {
        self.top.iter().find(|s| s.contains(e)).map(|s| s.name())
    }

    /// All registered sub-split decompositions, sorted by parent-split name
    /// (a deterministic iteration order — what
    /// [`crate::workflow::workflow_fingerprint`] hashes).
    pub fn sub_split_entries(&self) -> Vec<(&str, &[Split])> {
        let mut v: Vec<(&str, &[Split])> =
            self.subs.iter().map(|(k, s)| (k.as_str(), s.as_slice())).collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        v
    }
}

/// Bisect a weakly connected split into two weakly connected halves by
/// removing the spanning-tree edge with the most balanced subtree sizes.
/// Returns `None` if the split has a single entity.
pub fn bisect(g: &DependencyGraph, sp: &Split) -> Option<Vec<Split>> {
    let ents = sp.entities();
    if ents.len() < 2 {
        return None;
    }
    let adj = g.undirected_adjacency(ents);

    // Build a DFS spanning tree rooted at the first entity.
    let root = ents[0];
    let mut parent: FxHashMap<EntityId, EntityId> = FxHashMap::default();
    let mut order: Vec<EntityId> = Vec::with_capacity(ents.len());
    let mut seen: FxHashSet<EntityId> = FxHashSet::default();
    let mut stack = vec![root];
    seen.insert(root);
    while let Some(u) = stack.pop() {
        order.push(u);
        for &v in adj.get(&u).into_iter().flatten() {
            if seen.insert(v) {
                parent.insert(v, u);
                stack.push(v);
            }
        }
    }
    debug_assert_eq!(order.len(), ents.len(), "split must be weakly connected");

    // Subtree sizes via reverse DFS order.
    let mut size: FxHashMap<EntityId, usize> = ents.iter().map(|&e| (e, 1)).collect();
    for &u in order.iter().rev() {
        if let Some(&p) = parent.get(&u) {
            *size.get_mut(&p).unwrap() += size[&u];
        }
    }

    // Pick the non-root vertex whose subtree is closest to half.
    let n = ents.len();
    let best = order
        .iter()
        .filter(|e| parent.contains_key(e))
        .min_by_key(|e| (2 * size[e]).abs_diff(n))?;

    // Side A: best's subtree; side B: the rest.
    let mut side_a: FxHashSet<EntityId> = FxHashSet::default();
    let mut stack = vec![*best];
    while let Some(u) = stack.pop() {
        if !side_a.insert(u) {
            continue;
        }
        for (&child, &p) in &parent {
            if p == u && !side_a.contains(&child) {
                stack.push(child);
            }
        }
    }
    let a: Vec<EntityId> = ents.iter().copied().filter(|e| side_a.contains(e)).collect();
    let b: Vec<EntityId> = ents.iter().copied().filter(|e| !side_a.contains(e)).collect();
    debug_assert!(!a.is_empty() && !b.is_empty());
    Some(vec![
        Split::new(&format!("{}a", sp.name()), a),
        Split::new(&format!("{}b", sp.name()), b),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::curation::text_curation_workflow;

    #[test]
    fn bisect_halves_are_weakly_connected() {
        let (g, splits) = text_curation_workflow();
        for sp in splits.top_level() {
            let halves = bisect(&g, sp).expect("bisectable");
            assert_eq!(halves.len(), 2);
            let total: usize = halves.iter().map(|h| h.entities().len()).sum();
            assert_eq!(total, sp.entities().len());
            for h in &halves {
                assert!(
                    g.is_weakly_connected(h.entities()),
                    "half {} of {} not connected: {:?}",
                    h.name(),
                    sp.name(),
                    h.entities()
                );
            }
        }
    }

    #[test]
    fn bisect_single_entity_none() {
        let (g, _) = text_curation_workflow();
        let sp = Split::new("solo", vec![EntityId(0)]);
        assert!(bisect(&g, &sp).is_none());
    }

    #[test]
    fn registered_subsplits_preferred() {
        let (g, splits) = text_curation_workflow();
        let sp3 = splits.top_level().iter().find(|s| s.name() == "sp3").unwrap().clone();
        let subs = splits.get_sub_splits(&g, &sp3).unwrap();
        let names: Vec<&str> = subs.iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["sp4", "sp5"]);
    }

    #[test]
    fn computed_subsplits_for_unregistered() {
        let (g, splits) = text_curation_workflow();
        let sp2 = splits.top_level().iter().find(|s| s.name() == "sp2").unwrap().clone();
        let subs = splits.get_sub_splits(&g, &sp2).unwrap();
        assert_eq!(subs.len(), 2);
        for s in &subs {
            assert!(g.is_weakly_connected(s.entities()));
        }
    }

    #[test]
    fn recursive_bisection_terminates() {
        // Repeatedly bisecting must reach single-entity splits.
        let (g, splits) = text_curation_workflow();
        let mut queue: Vec<Split> = splits.top_level().to_vec();
        let mut rounds = 0;
        while let Some(sp) = queue.pop() {
            rounds += 1;
            assert!(rounds < 1000, "bisection does not terminate");
            if let Some(halves) = bisect(&g, &sp) {
                for h in halves {
                    assert!(h.entities().len() < sp.entities().len());
                    queue.push(h);
                }
            }
        }
    }

    #[test]
    fn split_of_maps_entities() {
        let (g, splits) = text_curation_workflow();
        let toks = g.entity_by_name("TOKS").unwrap();
        let mtrcs = g.entity_by_name("MTRCS").unwrap();
        assert_eq!(splits.split_of(toks), Some("sp1"));
        assert_eq!(splits.split_of(mtrcs), Some("sp3"));
    }
}
