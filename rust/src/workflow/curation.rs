//! The 29-entity text-curation workflow standing in for the paper's
//! Figure 1.
//!
//! The paper's workflow parses SEC/FDIC filings and extracts financial
//! metrics; its entity names are confidential acronyms, so ours are
//! synthetic but the *topology* follows the paper's description:
//!
//! * 3 input entities (`FINDOCS`, `IRP`, `P10FMD` — the paper names these),
//! * a parsing stage, an annotation/extraction stage, and a
//!   resolution/metrics stage,
//! * 29 entities total, organized so the three stage-aligned splits
//!   `sp1`, `sp2`, `sp3` are each weakly connected, and `sp3` further
//!   bisects into weakly connected `sp4`, `sp5` (the paper partitions
//!   `sp3` exactly this way when component LC2_lc1 resists splitting).

use super::graph::DependencyGraph;
use super::splits::{Split, SplitSet};
use crate::util::ids::EntityId;

/// Entity names per stage. `F10WMTR` and `MTRCS` appear in the paper's
/// prose ("tuples in table MTRCS are generated from tuples in table
/// F10WMTR"), so we keep those names and their relationship.
const SP1: [&str; 8] = ["FINDOCS", "IRP", "P10FMD", "DOCMETA", "SECTS", "PARAS", "SENTS", "TOKS"];
const SP2: [&str; 9] =
    ["ANNOTS", "NERS", "ORGS", "DATES", "AMTS", "METSPANS", "F10WMTR", "CANDS", "EVID"];
const SP4: [&str; 5] = ["RESOLVED", "LINKS", "MTRCS", "MTRVALS", "KBROWS"];
const SP5: [&str; 7] = ["KBATTRS", "AGGRS", "RPTROWS", "XREFS", "QCFLAGS", "PUBSNAP", "IDXMAP"];

/// Build the curation workflow and its canonical split decomposition.
///
/// Returns `(graph, splits)` where `splits` holds the top-level
/// `[sp1, sp2, sp3]` and knows how to bisect `sp3 → [sp4, sp5]`.
pub fn text_curation_workflow() -> (DependencyGraph, SplitSet) {
    let mut g = DependencyGraph::new();

    let e = |g: &mut DependencyGraph, name: &str, input: bool| g.add_entity(name, input);

    // ---- sp1: ingestion / parsing --------------------------------------
    let findocs = e(&mut g, "FINDOCS", true);
    let irp = e(&mut g, "IRP", true);
    let p10fmd = e(&mut g, "P10FMD", true);
    let docmeta = e(&mut g, "DOCMETA", false);
    let sects = e(&mut g, "SECTS", false);
    let paras = e(&mut g, "PARAS", false);
    let sents = e(&mut g, "SENTS", false);
    let toks = e(&mut g, "TOKS", false);

    g.add_derivation(findocs, docmeta);
    g.add_derivation(irp, docmeta); // registry info joins doc metadata
    g.add_derivation(findocs, sects);
    g.add_derivation(p10fmd, sects); // prior-filing map guides sectioning
    g.add_derivation(sects, paras);
    g.add_derivation(paras, sents);
    g.add_derivation(sents, toks);

    // ---- sp2: annotation / extraction -----------------------------------
    let annots = e(&mut g, "ANNOTS", false);
    let ners = e(&mut g, "NERS", false);
    let orgs = e(&mut g, "ORGS", false);
    let dates = e(&mut g, "DATES", false);
    let amts = e(&mut g, "AMTS", false);
    let metspans = e(&mut g, "METSPANS", false);
    let f10wmtr = e(&mut g, "F10WMTR", false);
    let cands = e(&mut g, "CANDS", false);
    let evid = e(&mut g, "EVID", false);

    g.add_derivation(toks, annots);
    g.add_derivation(sents, annots);
    g.add_derivation(annots, ners);
    g.add_derivation(ners, orgs);
    g.add_derivation(ners, dates);
    g.add_derivation(ners, amts);
    g.add_derivation(annots, metspans);
    g.add_derivation(metspans, f10wmtr);
    g.add_derivation(amts, f10wmtr);
    g.add_derivation(orgs, cands);
    g.add_derivation(dates, cands);
    g.add_derivation(f10wmtr, cands);
    g.add_derivation(metspans, evid);
    g.add_derivation(paras, evid); // evidence spans quote paragraphs

    // ---- sp3 = sp4 ∪ sp5: resolution / metrics / publication ------------
    let resolved = e(&mut g, "RESOLVED", false);
    let links = e(&mut g, "LINKS", false);
    let mtrcs = e(&mut g, "MTRCS", false);
    let mtrvals = e(&mut g, "MTRVALS", false);
    let kbrows = e(&mut g, "KBROWS", false);
    let kbattrs = e(&mut g, "KBATTRS", false);
    let aggrs = e(&mut g, "AGGRS", false);
    let rptrows = e(&mut g, "RPTROWS", false);
    let xrefs = e(&mut g, "XREFS", false);
    let qcflags = e(&mut g, "QCFLAGS", false);
    let pubsnap = e(&mut g, "PUBSNAP", false);
    let idxmap = e(&mut g, "IDXMAP", false);

    g.add_derivation(cands, resolved);
    g.add_derivation(evid, resolved);
    g.add_derivation(irp, resolved); // entity resolution against the registry
    g.add_derivation(resolved, links);
    g.add_derivation(f10wmtr, mtrcs); // the paper's named relationship
    g.add_derivation(resolved, mtrcs);
    g.add_derivation(mtrcs, mtrvals);
    g.add_derivation(links, kbrows);
    g.add_derivation(mtrvals, kbrows);
    g.add_derivation(kbrows, kbattrs);
    g.add_derivation(mtrvals, aggrs);
    g.add_derivation(aggrs, rptrows);
    g.add_derivation(kbattrs, rptrows);
    g.add_derivation(links, xrefs);
    g.add_derivation(xrefs, qcflags); // xrefs bridge sp4→sp5
    g.add_derivation(rptrows, qcflags);
    g.add_derivation(rptrows, pubsnap);
    g.add_derivation(pubsnap, idxmap);

    // ---- split decomposition --------------------------------------------
    let ids = |names: &[&str], g: &DependencyGraph| -> Vec<EntityId> {
        names.iter().map(|n| g.entity_by_name(n).expect("entity")).collect()
    };
    let sp1 = Split::new("sp1", ids(&SP1, &g));
    let sp2 = Split::new("sp2", ids(&SP2, &g));
    let sp3_entities: Vec<EntityId> = ids(&SP4, &g).into_iter().chain(ids(&SP5, &g)).collect();
    let sp3 = Split::new("sp3", sp3_entities);
    let sp4 = Split::new("sp4", ids(&SP4, &g));
    let sp5 = Split::new("sp5", ids(&SP5, &g));

    let splits = SplitSet::new(vec![sp1, sp2, sp3], vec![("sp3", vec![sp4, sp5])]);
    (g, splits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_29_entities_3_inputs() {
        let (g, _) = text_curation_workflow();
        assert_eq!(g.entity_count(), 29);
        let inputs: Vec<_> = g.entities().iter().filter(|e| e.is_input).collect();
        assert_eq!(inputs.len(), 3);
        let names: Vec<&str> = inputs.iter().map(|e| e.name.as_str()).collect();
        assert!(names.contains(&"FINDOCS") && names.contains(&"IRP") && names.contains(&"P10FMD"));
    }

    #[test]
    fn is_a_dag() {
        let (g, _) = text_curation_workflow();
        g.topo_order().unwrap();
    }

    #[test]
    fn every_split_weakly_connected() {
        let (g, splits) = text_curation_workflow();
        for sp in splits.top_level() {
            assert!(
                g.is_weakly_connected(sp.entities()),
                "split {} not weakly connected",
                sp.name()
            );
        }
        for sub in splits.sub_splits_of("sp3").unwrap() {
            assert!(
                g.is_weakly_connected(sub.entities()),
                "sub-split {} not weakly connected",
                sub.name()
            );
        }
    }

    #[test]
    fn splits_cover_all_entities_disjointly() {
        let (g, splits) = text_curation_workflow();
        let mut seen = rustc_hash::FxHashSet::default();
        let mut total = 0;
        for sp in splits.top_level() {
            for &e in sp.entities() {
                assert!(seen.insert(e), "entity in two splits");
                total += 1;
            }
        }
        assert_eq!(total, g.entity_count());
    }

    #[test]
    fn sub_splits_partition_sp3() {
        let (_, splits) = text_curation_workflow();
        let sp3 = splits.top_level().iter().find(|s| s.name() == "sp3").unwrap();
        let subs = splits.sub_splits_of("sp3").unwrap();
        let sub_total: usize = subs.iter().map(|s| s.entities().len()).sum();
        assert_eq!(sub_total, sp3.entities().len());
    }

    #[test]
    fn paper_named_relationship_present() {
        let (g, _) = text_curation_workflow();
        let f10wmtr = g.entity_by_name("F10WMTR").unwrap();
        let mtrcs = g.entity_by_name("MTRCS").unwrap();
        assert!(g.op_between(f10wmtr, mtrcs).is_some(), "MTRCS derived from F10WMTR");
    }

    #[test]
    fn mtrcs_only_after_f10wmtr_in_topo() {
        let (g, _) = text_curation_workflow();
        let order = g.topo_order().unwrap();
        let pos = |n: &str| order.iter().position(|&e| e == g.entity_by_name(n).unwrap()).unwrap();
        assert!(pos("F10WMTR") < pos("MTRCS"));
    }
}
