//! Synthetic provenance-trace generator.
//!
//! Stands in for the paper's confidential SEC/FDIC curation trace (532
//! documents → 4.6 M attribute-values, 6.4 M triples). The generator
//! reproduces the *structural statistics* the paper's algorithms are
//! sensitive to (§4 and Table 9):
//!
//! * ~428 K weakly connected components, almost all tiny (≤ 20 nodes);
//! * 132 mid-size components (910–7 453 nodes);
//! * three large components LC1/LC2/LC3 (1.2 M / 0.9 M / 0.7 M nodes) whose
//!   *split-induced* structure matches Table 9 — LC1/LC3 shatter under
//!   splits sp1/sp2/sp3, while LC2's sp3-induced subgraph stays one 0.9 M
//!   blob that only sub-splits sp4/sp5 break apart;
//! * a heavy-tailed fan-in distribution (a few values derived from
//!   100–450 parents, thousands from 10–100, the rest < 10) produced by
//!   resolution "hub" values — the paper's all-to-all UDF lineage.
//!
//! Every provenance edge parallels a workflow dependency edge (the paper's
//! transformations derive one table from its parent tables), which is what
//! makes Algorithm 3's split-induced decomposition effective.
//!
//! All dimensions scale by `scale_divisor` (1 = paper-fidelity, default 10
//! for a single-box base trace) and the whole trace replicates
//! `replication` times (the paper's ×9/×24/×48 scaled datasets — component
//! structure is preserved exactly, as in the paper).

use crate::provenance::model::{ProvTriple, Trace};
use crate::util::ids::{AttrValueId, EntityId, OpId};
use crate::util::rng::Pcg64;
use crate::workflow::curation::text_curation_workflow;
use crate::workflow::graph::DependencyGraph;
use crate::workflow::splits::SplitSet;
use rustc_hash::FxHashMap;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub seed: u64,
    /// Divide the paper's trace dimensions by this. 1 reproduces the full
    /// 4.6 M-node / 6.4 M-edge trace; the default 10 yields a ~0.5 M-node
    /// base trace suitable for a single box.
    pub scale_divisor: usize,
    /// Concatenate this many id-shifted copies of the base trace
    /// (the paper's scaled datasets use 9 / 24 / 48).
    pub replication: usize,
    /// Probability that a derived value picks one extra parent beyond the
    /// connectivity-guaranteeing interval assignment (controls edge/node
    /// density; the paper's trace has ~1.4 edges per node).
    pub extra_parent_prob: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self { seed: 0x5EC_F1D1C, scale_divisor: 10, replication: 1, extra_parent_prob: 0.25 }
    }
}

impl GeneratorConfig {
    /// Scale a paper-fidelity count, flooring at `floor`.
    fn sz(&self, paper: usize, floor: usize) -> usize {
        (paper / self.scale_divisor).max(floor)
    }
}

/// A materialized weakly connected set: its nodes grouped by entity.
#[derive(Debug, Default, Clone)]
struct MatSet {
    nodes: FxHashMap<EntityId, Vec<AttrValueId>>,
}

impl MatSet {
    fn of(&self, e: EntityId) -> &[AttrValueId] {
        self.nodes.get(&e).map(|v| v.as_slice()).unwrap_or(&[])
    }

}

/// Request for high-fan-in "hub" values inside a set (resolution UDFs).
#[derive(Debug, Clone, Copy)]
struct HubSpec {
    /// How many hub values to create.
    count: usize,
    /// Parent-count range for each hub value (clamped to layer size).
    lo: usize,
    hi: usize,
}

struct Ctx<'a> {
    g: &'a DependencyGraph,
    rng: Pcg64,
    next_serial: Vec<u64>,
    triples: Vec<ProvTriple>,
}

impl<'a> Ctx<'a> {
    fn new(g: &'a DependencyGraph, seed: u64) -> Self {
        Self {
            g,
            rng: Pcg64::new(seed),
            next_serial: vec![0; g.entity_count()],
            triples: Vec::new(),
        }
    }

    fn alloc(&mut self, e: EntityId) -> AttrValueId {
        let s = &mut self.next_serial[e.0 as usize];
        let id = AttrValueId::new(e, *s);
        *s += 1;
        id
    }

    fn alloc_n(&mut self, e: EntityId, n: usize) -> Vec<AttrValueId> {
        (0..n).map(|_| self.alloc(e)).collect()
    }

    fn edge(&mut self, src: AttrValueId, dst: AttrValueId, op: OpId) {
        self.triples.push(ProvTriple::new(src, dst, op));
    }

    fn op(&self, parent: EntityId, child: EntityId) -> OpId {
        self.g
            .op_between(parent, child)
            .unwrap_or_else(|| panic!("no dependency edge {:?}->{:?}", parent, child))
    }

    /// Materialize one weakly connected set along an entity `chain`
    /// (consecutive entities must be dependency-graph edges), spreading
    /// `n >= 1` nodes over the layers.
    ///
    /// Connectivity is guaranteed by the *interval assignment*: child `i`
    /// of a layer with `c` children takes parents `⌊i·p/c⌋ ..= ⌊(i+1)·p/c⌋`
    /// (clamped) from the `p`-parent layer; consecutive children overlap at
    /// the boundary parent, so each adjacent layer pair is weakly connected.
    /// Random extra parents (and optional hubs) add fan-in on top.
    fn materialize_chain_set(
        &mut self,
        chain: &[EntityId],
        n: usize,
        extra_parent_prob: f64,
        hub: Option<HubSpec>,
    ) -> MatSet {
        assert!(!chain.is_empty() && n >= 1);
        let layers = chain.len().min(n);
        // Node counts per layer: even split, remainder to the last layers
        // (later tables are usually wider in the paper's workflow).
        let base = n / layers;
        let rem = n % layers;
        let counts: Vec<usize> =
            (0..layers).map(|j| base + usize::from(j >= layers - rem)).collect();

        let mut set = MatSet::default();
        let mut prev: Vec<AttrValueId> = Vec::new();
        let mut prev_entity = chain[0];
        // Hub values go in the widest non-first layer.
        let hub_layer = hub.map(|_| {
            counts
                .iter()
                .enumerate()
                .skip(1)
                .max_by_key(|(_, &c)| c)
                .map(|(j, _)| j)
                .unwrap_or(0)
        });

        for (j, (&entity, &cnt)) in chain.iter().zip(&counts).enumerate() {
            let nodes = self.alloc_n(entity, cnt);
            if j > 0 {
                let op = self.op(prev_entity, entity);
                let p = prev.len();
                let c = nodes.len();
                for (i, &child) in nodes.iter().enumerate() {
                    let lo = i * p / c;
                    let hi = (((i + 1) * p) / c).min(p - 1).max(lo);
                    for &parent in &prev[lo..=hi] {
                        self.edge(parent, child, op);
                    }
                    if self.rng.chance(extra_parent_prob) {
                        let extra = prev[self.rng.range(0, p)];
                        self.edge(extra, child, op);
                    }
                }
                // Hub values: high fan-in from the previous layer.
                if hub_layer == Some(j) {
                    let h = hub.unwrap();
                    for _ in 0..h.count {
                        let hub_node = self.alloc(entity);
                        let fanin = self.rng.range(h.lo.min(p), (h.hi + 1).min(p + 1)).max(1);
                        // Sample a contiguous window (cheap, still "many
                        // parents"); UDF lineage is all-to-all anyway.
                        let start = self.rng.range(0, p - fanin + 1);
                        for &parent in &prev[start..start + fanin] {
                            self.edge(parent, hub_node, op);
                        }
                        set.nodes.entry(entity).or_default().push(hub_node);
                    }
                }
            }
            set.nodes.entry(entity).or_default().extend(&nodes);
            prev = nodes;
            prev_entity = entity;
        }
        set
    }

    /// Add `k` cross-split edges from `parent_set` to `child_set` along the
    /// dependency edge `pe → ce`. Both sets must populate those entities.
    fn cross_link(
        &mut self,
        parent_set: &MatSet,
        child_set: &MatSet,
        pe: EntityId,
        ce: EntityId,
        k: usize,
    ) {
        let op = self.op(pe, ce);
        let ps = parent_set.of(pe);
        let cs = child_set.of(ce);
        assert!(
            !ps.is_empty() && !cs.is_empty(),
            "cross_link: entities not populated ({} -> {})",
            self.g.name_of(pe),
            self.g.name_of(ce),
        );
        for _ in 0..k.max(1) {
            let src = ps[self.rng.range(0, ps.len())];
            let dst = cs[self.rng.range(0, cs.len())];
            self.edge(src, dst, op);
        }
    }

    /// Like [`Self::cross_link`], but tries the candidate dependency edges
    /// in order and uses the first whose entities both sets populate
    /// (small sets materialize only a chain prefix, so later entities may
    /// be absent). Panics if no candidate fits.
    fn cross_link_any(
        &mut self,
        parent_set: &MatSet,
        child_set: &MatSet,
        candidates: &[(EntityId, EntityId)],
        k: usize,
    ) {
        for &(pe, ce) in candidates {
            if !parent_set.of(pe).is_empty() && !child_set.of(ce).is_empty() {
                self.cross_link(parent_set, child_set, pe, ce, k);
                return;
            }
        }
        panic!("cross_link_any: no candidate edge applicable");
    }
}

/// Names of the canonical materialization chains (see `curation.rs`).
const SP1_CHAIN: [&str; 5] = ["FINDOCS", "SECTS", "PARAS", "SENTS", "TOKS"];
const SP1_IRP_CHAIN: [&str; 2] = ["IRP", "DOCMETA"];
const SP2_CHAIN: [&str; 4] = ["ANNOTS", "METSPANS", "F10WMTR", "CANDS"];
const SP4_CHAIN: [&str; 4] = ["RESOLVED", "MTRCS", "MTRVALS", "KBROWS"];
const SP5_CHAIN: [&str; 4] = ["KBATTRS", "RPTROWS", "PUBSNAP", "IDXMAP"];
/// Full-sp3 chain used for LC1/LC3 sets (crosses the sp4/sp5 boundary —
/// legal because those sets are small enough to never need sub-splitting).
const SP3_CHAIN: [&str; 6] = ["RESOLVED", "MTRCS", "MTRVALS", "KBROWS", "KBATTRS", "RPTROWS"];

fn ids(g: &DependencyGraph, names: &[&str]) -> Vec<EntityId> {
    names.iter().map(|n| g.entity_by_name(n).expect("chain entity")).collect()
}

/// Recipe for an LC1/LC3-shaped large component.
struct StagedLcRecipe {
    sp1_sets: usize,
    sp1_largest: usize,
    sp2_sets: usize,
    /// Paper-scaled sizes of the oversized sp2 sets (hubs).
    sp2_hubs: Vec<usize>,
    sp3_sets: usize,
    sp3_largest: usize,
    sp3_big_sets: usize,
}

/// Generate the base (un-replicated) trace.
fn generate_base(cfg: &GeneratorConfig, g: &DependencyGraph) -> Vec<ProvTriple> {
    let mut ctx = Ctx::new(g, cfg.seed);

    // ---- LC1 (paper: 1.2M nodes, 2.7M edges; Table 9 row 1) -------------
    staged_large_component(
        &mut ctx,
        cfg,
        &StagedLcRecipe {
            sp1_sets: 20,
            sp1_largest: cfg.sz(490, 8),
            sp2_sets: cfg.sz(29_696, 60),
            sp2_hubs: vec![cfg.sz(21_734, 40), cfg.sz(9_000, 25), cfg.sz(3_000, 15), cfg.sz(1_200, 12)],
            sp3_sets: cfg.sz(219_879, 300),
            sp3_largest: cfg.sz(3_291, 20),
            sp3_big_sets: 11,
        },
    );

    // ---- LC3 (0.7M nodes, 1.2M edges; Table 9 row 2) ---------------------
    staged_large_component(
        &mut ctx,
        cfg,
        &StagedLcRecipe {
            sp1_sets: 10,
            sp1_largest: cfg.sz(313, 8),
            sp2_sets: cfg.sz(15_491, 40),
            sp2_hubs: vec![cfg.sz(2_578, 30)],
            sp3_sets: cfg.sz(128_264, 200),
            sp3_largest: cfg.sz(643, 12),
            sp3_big_sets: 0,
        },
    );

    // ---- LC2 (0.9M nodes, 1.4M edges; the sp3-blob component) ------------
    lc2_component(&mut ctx, cfg);

    // ---- 132 mid-size components (910..7453 nodes) ------------------------
    mid_components(&mut ctx, cfg);

    // ---- ~428K small components (≤20 nodes) -------------------------------
    small_components(&mut ctx, cfg);

    ctx.triples
}

/// LC1/LC3 shape: 20-ish sp1 chains → thousands of sp2 sets (with hubs) →
/// hundreds of thousands of tiny sp3 sets. Connectivity: each sp2 set
/// derives from its cluster's sp1 set; hubs derive from *many* sp1 sets
/// (covering all of them); each sp3 set derives from sp2 sets within one
/// cluster (reproducing the paper's drill-down where 13 sp2 sets share a
/// single sp1 ancestor set).
fn staged_large_component(ctx: &mut Ctx, cfg: &GeneratorConfig, r: &StagedLcRecipe) {
    let g = ctx.g;
    let sp1_chain = ids(g, &SP1_CHAIN);
    let sp2_chain = ids(g, &SP2_CHAIN);
    let sp3_chain = ids(g, &SP3_CHAIN);
    let toks = g.entity_by_name("TOKS").unwrap();
    let sents = g.entity_by_name("SENTS").unwrap();
    let annots = g.entity_by_name("ANNOTS").unwrap();
    let cands = g.entity_by_name("CANDS").unwrap();
    let f10wmtr = g.entity_by_name("F10WMTR").unwrap();
    let resolved = g.entity_by_name("RESOLVED").unwrap();
    let mtrcs = g.entity_by_name("MTRCS").unwrap();
    let ep = cfg.extra_parent_prob;

    // sp1 sets. Sizes are floored at the chain length so every set
    // populates its exit entities (TOKS/SENTS feed sp2).
    let mut sp1_sets: Vec<MatSet> = Vec::with_capacity(r.sp1_sets);
    for i in 0..r.sp1_sets {
        let n = if i == 0 {
            r.sp1_largest
        } else {
            ctx.rng.range(r.sp1_largest / 4 + 5, r.sp1_largest + 1)
        }
        .max(sp1_chain.len());
        sp1_sets.push(ctx.materialize_chain_set(&sp1_chain, n, ep, None));
    }

    // sp2 sets, clustered by sp1 parent.
    let n_clusters = r.sp1_sets;
    let mut clusters: Vec<Vec<usize>> = vec![Vec::new(); n_clusters];
    let mut sp2_sets: Vec<MatSet> = Vec::with_capacity(r.sp2_sets);
    for i in 0..r.sp2_sets {
        let is_hub = i < r.sp2_hubs.len();
        let (n, hub) = if is_hub {
            let n = r.sp2_hubs[i];
            // Resolution hubs: a handful of values with 100–450 parents,
            // plus a sprinkling in the 10–100 band (paper fan-in stats).
            let hub = HubSpec { count: (n / 600).max(2), lo: 100, hi: 450 };
            (n, Some(hub))
        } else {
            // Floor at the chain length: sp2 sets act as *parents* of sp3
            // sets, so their exit entities (F10WMTR, CANDS) must exist.
            (ctx.rng.pareto_int(4, 12, 1.3) as usize, None)
        };
        let set = ctx.materialize_chain_set(&sp2_chain, n, ep, hub);
        // Wire to sp1: hubs cover every sp1 set; normal sets take their
        // cluster's set (and occasionally one more).
        if is_hub {
            for s1 in &sp1_sets {
                let pe = if ctx.rng.chance(0.5) { toks } else { sents };
                ctx.cross_link(s1, &set, pe, annots, 1 + (n / 64).min(16));
            }
            for c in &mut clusters {
                c.push(i);
            }
        } else {
            let cluster = i % n_clusters;
            ctx.cross_link(&sp1_sets[cluster].clone(), &set, toks, annots, 1);
            if ctx.rng.chance(0.12) {
                let other = ctx.rng.range(0, n_clusters);
                ctx.cross_link(&sp1_sets[other].clone(), &set, sents, annots, 1);
            }
            clusters[cluster].push(i);
        }
        sp2_sets.push(set);
    }

    // sp3 sets: mostly tiny; `sp3_big_sets` mid-size ones topped by
    // `sp3_largest`. Some sp3 sets take 10–100 extra parents (fan-in band).
    for i in 0..r.sp3_sets {
        let n = if i == 0 {
            r.sp3_largest
        } else if i <= r.sp3_big_sets {
            ctx.rng.range((r.sp3_largest / 3).max(3), r.sp3_largest + 1)
        } else {
            ctx.rng.pareto_int(2, 8, 1.4) as usize
        };
        let hub = if n >= 40 && ctx.rng.chance(0.3) {
            Some(HubSpec { count: 1, lo: 10, hi: 100 })
        } else {
            None
        };
        let set = ctx.materialize_chain_set(&sp3_chain, n, ep, hub);
        // Parent sp2 sets from one cluster (the paper's 13-sets-one-sp1
        // drill-down): usually 1, sometimes up to 15.
        let cluster = &clusters[ctx.rng.range(0, n_clusters)];
        let n_parents = {
            let x = ctx.rng.next_f64();
            if x < 0.80 {
                1
            } else if x < 0.95 {
                ctx.rng.range(2, 5)
            } else {
                ctx.rng.range(5, 16)
            }
        }
        .min(cluster.len());
        for p in 0..n_parents {
            let sp2_idx = cluster[ctx.rng.range(0, cluster.len().max(1))];
            let prefer_mtr = p != 0 && !ctx.rng.chance(0.7);
            let cands_first = [(cands, resolved), (f10wmtr, mtrcs)];
            let mtr_first = [(f10wmtr, mtrcs), (cands, resolved)];
            let order: &[(_, _)] = if prefer_mtr { &mtr_first } else { &cands_first };
            ctx.cross_link_any(&sp2_sets[sp2_idx].clone(), &set, order, 1);
        }
    }
}

/// LC2 shape (paper Table 9 row 3): one 4-node sp1 set (registry values),
/// one ~211-node sp2 set, and a 0.9M-node sp3-induced *single* component
/// that only the sp4/sp5 sub-splits break into ~197K sets (two of them
/// ≥1000 nodes, the largest ~24733).
fn lc2_component(ctx: &mut Ctx, cfg: &GeneratorConfig) {
    let g = ctx.g;
    let irp = g.entity_by_name("IRP").unwrap();
    let resolved = g.entity_by_name("RESOLVED").unwrap();
    let cands = g.entity_by_name("CANDS").unwrap();
    let kbrows = g.entity_by_name("KBROWS").unwrap();
    let kbattrs = g.entity_by_name("KBATTRS").unwrap();
    let sp1_irp_chain = ids(g, &SP1_IRP_CHAIN);
    let sp2_chain = ids(g, &SP2_CHAIN);
    let sp4_chain = ids(g, &SP4_CHAIN);
    let sp5_chain = ids(g, &SP5_CHAIN);
    let ep = cfg.extra_parent_prob;

    // sp1: exactly 4 nodes (1 IRP + 3 DOCMETA) — unscaled, as in the paper.
    let sp1_set = ctx.materialize_chain_set(&sp1_irp_chain, 4, 0.0, None);
    // sp2: one ~211-node set.
    let sp2_set = ctx.materialize_chain_set(&sp2_chain, cfg.sz(211, 24), ep, None);

    // sp4 side: many tiny sets (≤30 nodes).
    let n_sp4 = cfg.sz(64_737, 120);
    let mut sp4_sets: Vec<MatSet> = Vec::with_capacity(n_sp4);
    for _ in 0..n_sp4 {
        // Floor at the chain length so KBROWS (the sp4 → sp5 exit) exists.
        let n = ctx.rng.pareto_int(sp4_chain.len() as u64, 30, 1.5) as usize;
        sp4_sets.push(ctx.materialize_chain_set(&sp4_chain, n, ep, None));
    }

    // sp5 side: two hubs + many tiny sets.
    let n_sp5 = cfg.sz(132_599, 200);
    let hub0 = ctx.materialize_chain_set(
        &sp5_chain,
        cfg.sz(24_733, 60),
        ep,
        Some(HubSpec { count: 4, lo: 100, hi: 450 }),
    );
    let hub1 = ctx.materialize_chain_set(
        &sp5_chain,
        cfg.sz(3_000, 30),
        ep,
        Some(HubSpec { count: 2, lo: 10, hi: 100 }),
    );
    let mut sp5_sets: Vec<MatSet> = Vec::with_capacity(n_sp5);
    for _ in 0..n_sp5.saturating_sub(2) {
        let n = ctx.rng.pareto_int(2, 8, 1.5) as usize;
        sp5_sets.push(ctx.materialize_chain_set(&sp5_chain, n, ep, None));
    }

    // Wiring.
    // (a) Every sp4 set feeds hub0 (KBROWS → KBATTRS): this is what makes
    //     G[V(sp3, LC2)] a single component — remove the sub-splits and the
    //     whole sp3 projection is connected through the hub.
    for s4 in &sp4_sets {
        ctx.cross_link(&s4.clone(), &hub0, kbrows, kbattrs, 1);
    }
    // (b) Each non-hub sp5 set derives from a random sp4 set; a few also
    //     touch hub1's cluster.
    for i in 0..sp5_sets.len() {
        let s4 = sp4_sets[ctx.rng.range(0, sp4_sets.len())].clone();
        ctx.cross_link(&s4, &sp5_sets[i].clone(), kbrows, kbattrs, 1);
    }
    let s4 = sp4_sets[0].clone();
    ctx.cross_link(&s4, &hub1, kbrows, kbattrs, 2);
    // (c) The registry IRP value resolves into ~5% of sp4 sets (all-to-all
    //     UDF → huge fan-out, and RESOLVED values with extra parents).
    let n_linked = (n_sp4 / 20).max(2);
    for i in 0..n_linked {
        let idx = (i * sp4_sets.len()) / n_linked;
        let s4 = sp4_sets[idx].clone();
        ctx.cross_link(&sp1_set, &s4, irp, resolved, 1);
    }
    // (d) The sp2 set feeds a couple of sp4 sets (CANDS → RESOLVED).
    for _ in 0..(n_sp4 / 50).max(2) {
        let idx = ctx.rng.range(0, sp4_sets.len());
        let s4 = sp4_sets[idx].clone();
        ctx.cross_link(&sp2_set, &s4, cands, resolved, 1);
    }
}

/// 132 mid-size components: single long chains across all three splits
/// (sp1 → sp2 → sp3 via cross-links). Deep layered lineages give the
/// SC-SL / LC-SL query classes their 100–200-ancestor items.
fn mid_components(ctx: &mut Ctx, cfg: &GeneratorConfig) {
    let g = ctx.g;
    let sp1_chain = ids(g, &SP1_CHAIN);
    let sp2_chain = ids(g, &SP2_CHAIN);
    let sp3_chain = ids(g, &SP3_CHAIN);
    let toks = g.entity_by_name("TOKS").unwrap();
    let annots = g.entity_by_name("ANNOTS").unwrap();
    let cands = g.entity_by_name("CANDS").unwrap();
    let resolved = g.entity_by_name("RESOLVED").unwrap();
    let ep = cfg.extra_parent_prob;

    let lo = cfg.sz(910, 40);
    let hi = cfg.sz(7_453, 120);
    for i in 0..132 {
        // One component pinned at the top of the band (the paper's SC-SL
        // class queries a 7453-node component), one at the bottom.
        let n = match i {
            0 => hi,
            1 => lo,
            _ => ctx.rng.range(lo, hi + 1),
        };
        let n1 = (n / 5).max(sp1_chain.len());
        let n2 = (2 * n / 5).max(sp2_chain.len());
        let n3 = n.saturating_sub(n1 + n2);
        let s1 = ctx.materialize_chain_set(&sp1_chain, n1, ep, None);
        let hub2 = if n2 >= 60 {
            Some(HubSpec { count: 2, lo: 10, hi: 100 })
        } else {
            None
        };
        let s2 = ctx.materialize_chain_set(&sp2_chain, n2, ep, hub2);
        let s3 = ctx.materialize_chain_set(&sp3_chain, n3.max(2), ep, None);
        ctx.cross_link(&s1, &s2, toks, annots, (n1 / 10).max(2));
        ctx.cross_link(&s2, &s3, cands, resolved, (n2 / 10).max(2));
    }
}

/// The long tail: hundreds of thousands of tiny components (≤ 20 nodes),
/// 60% fully inside sp1, 40% crossing sp1 → sp2.
fn small_components(ctx: &mut Ctx, cfg: &GeneratorConfig) {
    let g = ctx.g;
    let sp1_chain = ids(g, &SP1_CHAIN);
    let sp2_chain = ids(g, &SP2_CHAIN);
    let toks = g.entity_by_name("TOKS").unwrap();
    let annots = g.entity_by_name("ANNOTS").unwrap();

    let sents = g.entity_by_name("SENTS").unwrap();
    let count = cfg.sz(427_865, 800); // 428K total minus the 135 big/mid
    for _ in 0..count {
        let n = ctx.rng.pareto_int(2, 20, 1.6) as usize;
        // Crossing components need the sp1 side to reach SENTS/TOKS.
        if n < 6 || ctx.rng.chance(0.6) {
            ctx.materialize_chain_set(&sp1_chain, n, 0.1, None);
        } else {
            let n1 = (n / 2).max(4);
            let s1 = ctx.materialize_chain_set(&sp1_chain, n1, 0.1, None);
            let s2 = ctx.materialize_chain_set(&sp2_chain, n.saturating_sub(n1).max(1), 0.1, None);
            ctx.cross_link_any(&s1, &s2, &[(toks, annots), (sents, annots)], 1);
        }
    }
}

/// Generate a trace with the canonical curation workflow. Returns the
/// workflow objects alongside so callers share one construction.
pub fn generate(cfg: &GeneratorConfig) -> (Trace, DependencyGraph, SplitSet) {
    let (g, splits) = text_curation_workflow();
    let trace = generate_with(cfg, &g);
    (trace, g, splits)
}

/// Generate a trace against an explicit workflow graph.
pub fn generate_with(cfg: &GeneratorConfig, g: &DependencyGraph) -> Trace {
    assert!(cfg.scale_divisor >= 1, "scale_divisor must be >= 1");
    assert!(cfg.replication >= 1, "replication must be >= 1");
    let base = generate_base(cfg, g);

    if cfg.replication == 1 {
        return Trace::new(base);
    }
    // Replicate with a per-entity serial shift so copies never collide;
    // component structure is preserved exactly (paper §4, Scaled Datasets).
    let mut strides = vec![0u64; g.entity_count()];
    for t in &base {
        for id in [t.src, t.dst] {
            let e = id.entity().0 as usize;
            strides[e] = strides[e].max(id.serial() + 1);
        }
    }
    let mut out = Vec::with_capacity(base.len() * cfg.replication);
    out.extend_from_slice(&base);
    for rep in 1..cfg.replication as u64 {
        for t in &base {
            let shift = |id: AttrValueId| {
                AttrValueId::new(id.entity(), id.serial() + rep * strides[id.entity().0 as usize])
            };
            out.push(ProvTriple::new(shift(t.src), shift(t.dst), t.op));
        }
    }
    Trace::new(out)
}

/// Structural statistics of a trace (computed with a union-find; used by
/// `provspark stats`, tests, and EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct TraceStats {
    pub nodes: usize,
    pub edges: usize,
    pub components: usize,
    /// (nodes, edges) of the largest components, descending by nodes.
    pub largest: Vec<(usize, usize)>,
    /// Components with 20 < nodes < threshold_large.
    pub mid_components: usize,
    /// Fan-in histogram: values with <10, 10..100, 100.. parents (+max).
    pub fanin_lt10: usize,
    pub fanin_10_100: usize,
    pub fanin_ge100: usize,
    pub fanin_max: usize,
}

impl TraceStats {
    /// Compute stats. `mid_lo`/`large_lo` bound the mid-size band in nodes
    /// (the paper uses >20 and <~0.1M; pass scaled values).
    pub fn compute(trace: &Trace, mid_lo: usize, large_lo: usize) -> Self {
        use crate::provenance::wcc::UnionFind;
        let mut uf = UnionFind::new();
        for t in &trace.triples {
            uf.union(t.src.raw(), t.dst.raw());
        }
        // Component sizes.
        let ids: Vec<u64> = uf.keys().collect();
        let mut comp_nodes: FxHashMap<u64, usize> = FxHashMap::default();
        for id in ids {
            *comp_nodes.entry(uf.find(id)).or_default() += 1;
        }
        let mut comp_edges: FxHashMap<u64, usize> = FxHashMap::default();
        for t in &trace.triples {
            *comp_edges.entry(uf.find(t.src.raw())).or_default() += 1;
        }
        let mut sizes: Vec<(usize, usize, u64)> = comp_nodes
            .iter()
            .map(|(&root, &n)| (n, comp_edges.get(&root).copied().unwrap_or(0), root))
            .collect();
        sizes.sort_unstable_by(|a, b| b.0.cmp(&a.0));

        // Fan-in histogram.
        let mut fanin: FxHashMap<u64, usize> = FxHashMap::default();
        for t in &trace.triples {
            *fanin.entry(t.dst.raw()).or_default() += 1;
        }
        let mut s = TraceStats {
            nodes: comp_nodes.values().sum(),
            edges: trace.triples.len(),
            components: comp_nodes.len(),
            largest: sizes.iter().take(5).map(|&(n, e, _)| (n, e)).collect(),
            mid_components: sizes
                .iter()
                .filter(|&&(n, _, _)| n > mid_lo && n < large_lo)
                .count(),
            ..Default::default()
        };
        for &f in fanin.values() {
            if f < 10 {
                s.fanin_lt10 += 1;
            } else if f < 100 {
                s.fanin_10_100 += 1;
            } else {
                s.fanin_ge100 += 1;
            }
            s.fanin_max = s.fanin_max.max(f);
        }
        s
    }

    pub fn summary(&self) -> String {
        use crate::util::fmt::human_count;
        format!(
            "nodes={} edges={} components={} largest={:?} mid={} fanin(<10/10-100/≥100)={}/{}/{} max_fanin={}",
            human_count(self.nodes as u64),
            human_count(self.edges as u64),
            human_count(self.components as u64),
            self.largest,
            self.mid_components,
            self.fanin_lt10,
            self.fanin_10_100,
            self.fanin_ge100,
            self.fanin_max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> GeneratorConfig {
        // Very small for fast unit tests; structure checks live in
        // rust/tests/generator_stats.rs at a more realistic scale.
        GeneratorConfig { scale_divisor: 1000, ..Default::default() }
    }

    #[test]
    fn generates_nonempty_dag_per_op() {
        let (trace, g, _) = generate(&tiny_cfg());
        assert!(!trace.is_empty());
        // Every edge parallels a dependency edge with the matching op.
        for t in &trace.triples {
            let op = g.op_between(t.src.entity(), t.dst.entity());
            assert_eq!(op, Some(t.op), "edge {:?} violates workflow graph", t);
        }
    }

    #[test]
    fn deterministic() {
        let (a, _, _) = generate(&tiny_cfg());
        let (b, _, _) = generate(&tiny_cfg());
        assert_eq!(a.triples, b.triples);
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _, _) = generate(&tiny_cfg());
        let (b, _, _) = generate(&GeneratorConfig { seed: 99, ..tiny_cfg() });
        assert_ne!(a.triples, b.triples);
    }

    #[test]
    fn replication_multiplies_exactly() {
        let (base, _, _) = generate(&tiny_cfg());
        let (tripled, _, _) = generate(&GeneratorConfig { replication: 3, ..tiny_cfg() });
        assert_eq!(tripled.len(), base.len() * 3);
        assert_eq!(tripled.node_count(), base.node_count() * 3);
        // Components triple too.
        let sb = TraceStats::compute(&base, 20, 10_000);
        let st = TraceStats::compute(&tripled, 20, 10_000);
        assert_eq!(st.components, sb.components * 3);
        assert_eq!(st.largest[0].0, sb.largest[0].0, "largest component size preserved");
    }

    #[test]
    fn stats_have_three_large_components() {
        let (trace, _, _) = generate(&tiny_cfg());
        let s = TraceStats::compute(&trace, 20, 1_000);
        assert!(s.components > 100, "components={}", s.components);
        assert!(s.largest.len() >= 3);
        // The top-3 are well above the rest.
        assert!(s.largest[2].0 > 5 * 20, "{:?}", s.largest);
        // At divisor 1000 the hub layers shrink to ~10 nodes, capping the
        // achievable fan-in; the full 100–450 band is asserted at a more
        // realistic scale in rust/tests/generator_stats.rs.
        assert!(s.fanin_max >= 10, "hub fan-in missing: max={}", s.fanin_max);
    }
}
