//! The workflow side of the paper: the dependency graph among entities
//! (tables), the split machinery Algorithm 3 consumes, and the synthetic
//! text-curation workload that stands in for the paper's confidential
//! SEC/FDIC provenance trace (see DESIGN.md §2 for the substitution
//! rationale).

pub mod curation;
pub mod generator;
pub mod graph;
pub mod splits;

pub use curation::text_curation_workflow;
pub use generator::{GeneratorConfig, TraceStats};
pub use graph::{DependencyGraph, EntityInfo};
pub use splits::{Split, SplitSet};

use crate::util::rng::mix64;

/// A deterministic 64-bit fingerprint of a workflow: the dependency graph
/// (entities, derivation edges) plus the split decomposition Algorithm 3
/// partitions against. Two calls agree iff the workflow is structurally
/// identical, across processes and runs (no hasher randomization).
///
/// Recorded in [`Preprocessed::workflow_fingerprint`] by
/// [`preprocess`](crate::provenance::pipeline::preprocess) and persisted in
/// the v3 store header, so
/// [`IncrementalIndex::new`](crate::provenance::incremental::IncrementalIndex::new)
/// can refuse to ingest under a workflow the index was not built with.
///
/// [`Preprocessed::workflow_fingerprint`]: crate::provenance::pipeline::Preprocessed::workflow_fingerprint
pub fn workflow_fingerprint(graph: &DependencyGraph, splits: &SplitSet) -> u64 {
    fn fold(h: u64, x: u64) -> u64 {
        mix64(h ^ x.wrapping_add(0x9E37_79B9_7F4A_7C15))
    }
    fn fold_str(mut h: u64, s: &str) -> u64 {
        h = fold(h, s.len() as u64);
        for b in s.bytes() {
            h = fold(h, b as u64);
        }
        h
    }
    fn fold_split(mut h: u64, sp: &Split) -> u64 {
        h = fold_str(h, sp.name());
        h = fold(h, sp.entities().len() as u64);
        for &e in sp.entities() {
            h = fold(h, e.0 as u64);
        }
        h
    }

    let mut h: u64 = 0x5057_464C_4F57_0001; // "PWFLOW" domain tag, version 1
    h = fold(h, graph.entities().len() as u64);
    for e in graph.entities() {
        h = fold(h, e.id.0 as u64);
        h = fold(h, e.is_input as u64);
        h = fold_str(h, &e.name);
    }
    h = fold(h, graph.edges().len() as u64);
    for d in graph.edges() {
        h = fold(h, d.parent.0 as u64);
        h = fold(h, d.child.0 as u64);
        h = fold(h, d.op.0 as u64);
    }
    h = fold(h, splits.top_level().len() as u64);
    for sp in splits.top_level() {
        h = fold_split(h, sp);
    }
    let subs = splits.sub_split_entries();
    h = fold(h, subs.len() as u64);
    for (name, group) in subs {
        h = fold_str(h, name);
        h = fold(h, group.len() as u64);
        for sp in group {
            h = fold_split(h, sp);
        }
    }
    // 0 is reserved for "unrecorded" (legacy store files).
    h.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_deterministic_and_structure_sensitive() {
        let (g, s) = text_curation_workflow();
        let fp = workflow_fingerprint(&g, &s);
        assert_ne!(fp, 0);
        let (g2, s2) = text_curation_workflow();
        assert_eq!(fp, workflow_fingerprint(&g2, &s2), "same workflow, same fingerprint");

        // Any structural change moves the fingerprint.
        let (mut g3, s3) = text_curation_workflow();
        g3.add_entity("XTRA", false);
        assert_ne!(fp, workflow_fingerprint(&g3, &s3));
        let (mut g4, s4) = text_curation_workflow();
        let a = g4.entities()[0].id;
        let b = g4.entities()[1].id;
        g4.add_derivation(b, a);
        assert_ne!(fp, workflow_fingerprint(&g4, &s4));
    }
}
