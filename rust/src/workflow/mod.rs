//! The workflow side of the paper: the dependency graph among entities
//! (tables), the split machinery Algorithm 3 consumes, and the synthetic
//! text-curation workload that stands in for the paper's confidential
//! SEC/FDIC provenance trace (see DESIGN.md §2 for the substitution
//! rationale).

pub mod curation;
pub mod generator;
pub mod graph;
pub mod splits;

pub use curation::text_curation_workflow;
pub use generator::{GeneratorConfig, TraceStats};
pub use graph::{DependencyGraph, EntityInfo};
pub use splits::{Split, SplitSet};
