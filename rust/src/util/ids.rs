//! Identifier types for the provenance data model.
//!
//! An attribute-value id packs its owning entity (table) into the high 16
//! bits and a per-entity serial into the low 48 bits. This mirrors the
//! paper's need (§3, Algorithm 3) to map any vertex of the provenance graph
//! back to its workflow table without a lookup table: `V(sp, c)` — "the
//! vertices in component `c` which belong to a table in split `sp`" — is
//! then computable from the id alone.

use std::fmt;

/// A workflow entity (table) id. The paper's workflow has 29 entities.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EntityId(pub u16);

/// A transformation (operator) id: `op` in the `⟨src, dst, op⟩` triples.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// An attribute-value id (a vertex of the provenance graph).
///
/// Layout: `[entity:16][serial:48]`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AttrValueId(pub u64);

const SERIAL_BITS: u32 = 48;
const SERIAL_MASK: u64 = (1u64 << SERIAL_BITS) - 1;

impl AttrValueId {
    /// Pack an entity id and serial into an attribute-value id.
    #[inline]
    pub fn new(entity: EntityId, serial: u64) -> Self {
        debug_assert!(serial <= SERIAL_MASK, "serial overflow: {serial}");
        Self(((entity.0 as u64) << SERIAL_BITS) | (serial & SERIAL_MASK))
    }

    /// The entity (table) this attribute-value belongs to.
    #[inline]
    pub fn entity(self) -> EntityId {
        EntityId((self.0 >> SERIAL_BITS) as u16)
    }

    /// The per-entity serial number.
    #[inline]
    pub fn serial(self) -> u64 {
        self.0 & SERIAL_MASK
    }

    /// Raw u64 representation (used by the store and the XLA remap glue).
    #[inline]
    pub fn raw(self) -> u64 {
        self.0
    }
}

impl fmt::Debug for AttrValueId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "av({}:{})", self.entity().0, self.serial())
    }
}

impl fmt::Display for AttrValueId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.entity().0, self.serial())
    }
}

/// Id of a weakly connected component. By convention this is the minimum
/// raw [`AttrValueId`] in the component (what min-label propagation yields).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ComponentId(pub u64);

/// Id of a weakly connected set (a partition of a large component, or a
/// whole small component managed as a single set — see §2.3 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SetId(pub u64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for e in [0u16, 1, 28, 65535] {
            for s in [0u64, 1, 12345, SERIAL_MASK] {
                let id = AttrValueId::new(EntityId(e), s);
                assert_eq!(id.entity(), EntityId(e));
                assert_eq!(id.serial(), s);
            }
        }
    }

    #[test]
    fn ordering_groups_by_entity() {
        let a = AttrValueId::new(EntityId(1), u64::from(u32::MAX));
        let b = AttrValueId::new(EntityId(2), 0);
        assert!(a < b);
    }

    #[test]
    fn display() {
        let id = AttrValueId::new(EntityId(3), 42);
        assert_eq!(format!("{id}"), "3:42");
    }
}
