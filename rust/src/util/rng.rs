//! Deterministic, dependency-free random number generators.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! two generators the project needs:
//!
//! * [`SplitMix64`] — fast, tiny-state; used for seeding and hashing-like
//!   scrambling.
//! * [`Pcg64`] — PCG-XSL-RR 128/64; the workhorse generator used by the
//!   workload generator and the property-test harness. Deterministic across
//!   platforms for a given seed, which keeps every experiment reproducible.

/// SplitMix64 (Steele et al.). Mainly used to expand a single `u64` seed
/// into the larger state of [`Pcg64`], and as a cheap stateless scrambler.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Stateless SplitMix64 finalizer — good avalanche, used for deterministic
/// per-key scrambling (e.g. hash partitioning of synthetic ids).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSL-RR 128/64 — O'Neill's PCG family member with 128-bit state and
/// 64-bit output. Plenty for workload synthesis; not cryptographic.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed the generator. Two different seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        // Warm up to decorrelate from the seed expansion.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a discrete distribution given cumulative weights.
    /// `cum` must be non-empty, non-decreasing, with `cum.last() > 0`.
    pub fn pick_weighted(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty weights");
        let x = self.next_f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }

    /// A power-law-ish integer in `[lo, hi]` biased toward `lo`
    /// (Pareto-shaped with exponent `alpha > 0`); used to synthesize the
    /// paper's heavy-tailed fan-in distribution.
    pub fn pareto_int(&mut self, lo: u64, hi: u64, alpha: f64) -> u64 {
        assert!(lo >= 1 && hi >= lo && alpha > 0.0);
        let u = self.next_f64().max(1e-12);
        let lo_f = lo as f64;
        let hi_f = hi as f64 + 1.0;
        // Inverse-CDF of a bounded Pareto.
        let la = lo_f.powf(-alpha);
        let ha = hi_f.powf(-alpha);
        let x = (la - u * (la - ha)).powf(-1.0 / alpha);
        (x as u64).clamp(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_deterministic_and_distinct_streams() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(1);
        let mut c = Pcg64::new(2);
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..64).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Pcg64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_small_range() {
        let mut r = Pcg64::new(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.next_below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pareto_bounds_and_bias() {
        let mut r = Pcg64::new(9);
        let mut lo_count = 0;
        for _ in 0..2000 {
            let x = r.pareto_int(1, 450, 1.2);
            assert!((1..=450).contains(&x));
            if x <= 9 {
                lo_count += 1;
            }
        }
        // Heavy bias toward the low end, as the paper's fan-in stats show.
        assert!(lo_count > 1500, "lo_count={lo_count}");
    }

    #[test]
    fn pick_weighted_respects_zero_weight() {
        let mut r = Pcg64::new(13);
        // weights [0.0, 1.0] as cumulative [0.0, 1.0]: index 0 never picked
        for _ in 0..200 {
            assert_eq!(r.pick_weighted(&[0.0, 1.0]), 1);
        }
    }
}
