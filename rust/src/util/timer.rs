//! A tiny scoped timer used throughout the pipeline and the experiment
//! harness to report phase timings.

use std::time::{Duration, Instant};

/// Wall-clock timer with named laps.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Record the time since the previous lap (or construction) under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    /// Total elapsed time since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    /// Recorded laps, in order.
    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    /// Render laps as `name=dur` pairs, for log lines.
    pub fn summary(&self) -> String {
        self.laps
            .iter()
            .map(|(n, d)| format!("{n}={}", super::fmt::human_duration(*d)))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Time a closure, returning `(result, elapsed)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut t = Timer::new();
        std::thread::sleep(Duration::from_millis(2));
        t.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        t.lap("b");
        assert_eq!(t.laps().len(), 2);
        assert!(t.total() >= Duration::from_millis(4));
        assert!(t.summary().contains("a="));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d < Duration::from_secs(1));
    }
}
