//! Human-readable formatting for counts, byte sizes and durations —
//! used by the CLI, the bench harness and the experiment reports.

use std::time::Duration;

/// `1234567` → `"1.23M"`.
pub fn human_count(n: u64) -> String {
    let nf = n as f64;
    if n >= 1_000_000_000 {
        format!("{:.2}B", nf / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", nf / 1e6)
    } else if n >= 10_000 {
        format!("{:.1}K", nf / 1e3)
    } else {
        n.to_string()
    }
}

/// `1536` → `"1.50 KiB"`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty duration: picks ns/µs/ms/s to keep 3 significant-ish digits.
pub fn human_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns < 60 * 1_000_000_000u128 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else {
        let secs = d.as_secs();
        format!("{}m{:02}s", secs / 60, secs % 60)
    }
}

/// Right-pad a string to `w` chars (for plain-text tables).
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(w - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(10_000), "10.0K");
        assert_eq!(human_count(6_400_000), "6.40M");
        assert_eq!(human_count(2_500_000_000), "2.50B");
    }

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(human_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(human_duration(Duration::from_secs(90)), "1m30s");
    }

    #[test]
    fn padding() {
        assert_eq!(pad("ab", 4), "ab  ");
        assert_eq!(pad("abcde", 3), "abcde");
    }
}
