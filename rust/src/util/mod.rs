//! Small shared utilities: deterministic RNGs, id codecs, timers and
//! human-readable formatting.

pub mod fmt;
pub mod ids;
pub mod rng;
pub mod timer;

pub use fmt::{human_bytes, human_count, human_duration};
pub use ids::{AttrValueId, EntityId, OpId};
pub use rng::{Pcg64, SplitMix64};
pub use timer::Timer;
