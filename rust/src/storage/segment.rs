//! Fixed-record segment files (`PSPKSEG1`): the on-disk half of the
//! out-of-core store.
//!
//! A segment file holds the partitions of one dataset back-to-back, each
//! as a run of fixed-size little-endian records, behind a directory of
//! per-segment row counts. Offsets are derivable from the directory, so
//! [`SegmentFile::read_segment`] is one `seek` + one sized read — a single
//! partition is loadable without touching the rest of the file, which is
//! what makes demand paging proportional to the data a query touches.
//!
//! Layout:
//!
//! ```text
//! "PSPKSEG1" | u64 record_bytes | u64 seg_count | seg_count × u64 rows | payload…
//! ```
//!
//! Row types implement [`SegmentCodec`] (the same wire layout the
//! preprocessed store uses: ids as `u64`, ops as `u32`). Corrupt or
//! truncated files surface as errors naming the path; every read/write
//! passes an `io:segment` fault probe so the deterministic fault plans
//! cover this tier too.

use crate::fault::{io_probe, FaultSite};
use crate::provenance::model::{CcTriple, CsTriple, ProvTriple, SetDep};
use crate::util::ids::{AttrValueId, ComponentId, OpId, SetId};
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC_SEG: &[u8; 8] = b"PSPKSEG1";

/// Fixed-size binary row codec for segment files. `RECORD_BYTES` is the
/// exact on-disk size of one record; `decode` receives exactly that many
/// bytes.
pub trait SegmentCodec: Sized {
    const RECORD_BYTES: usize;
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(b: &[u8]) -> Self;
}

#[inline]
fn get_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

#[inline]
fn get_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}

impl SegmentCodec for ProvTriple {
    const RECORD_BYTES: usize = 20;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.src.raw().to_le_bytes());
        out.extend_from_slice(&self.dst.raw().to_le_bytes());
        out.extend_from_slice(&self.op.0.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        ProvTriple::new(
            AttrValueId(get_u64(b, 0)),
            AttrValueId(get_u64(b, 8)),
            OpId(get_u32(b, 16)),
        )
    }
}

impl SegmentCodec for CcTriple {
    const RECORD_BYTES: usize = 28;

    fn encode(&self, out: &mut Vec<u8>) {
        self.triple.encode(out);
        out.extend_from_slice(&self.ccid.0.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        CcTriple { triple: ProvTriple::decode(&b[..20]), ccid: ComponentId(get_u64(b, 20)) }
    }
}

impl SegmentCodec for CsTriple {
    const RECORD_BYTES: usize = 36;

    fn encode(&self, out: &mut Vec<u8>) {
        self.triple.encode(out);
        out.extend_from_slice(&self.src_csid.0.to_le_bytes());
        out.extend_from_slice(&self.dst_csid.0.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        CsTriple {
            triple: ProvTriple::decode(&b[..20]),
            src_csid: SetId(get_u64(b, 20)),
            dst_csid: SetId(get_u64(b, 28)),
        }
    }
}

impl SegmentCodec for SetDep {
    const RECORD_BYTES: usize = 16;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.src_csid.0.to_le_bytes());
        out.extend_from_slice(&self.dst_csid.0.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        SetDep { src_csid: SetId(get_u64(b, 0)), dst_csid: SetId(get_u64(b, 8)) }
    }
}

impl SegmentCodec for (u64, u64) {
    const RECORD_BYTES: usize = 16;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
        out.extend_from_slice(&self.1.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        (get_u64(b, 0), get_u64(b, 8))
    }
}

impl SegmentCodec for (u64, u64, u64) {
    const RECORD_BYTES: usize = 24;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
        out.extend_from_slice(&self.1.to_le_bytes());
        out.extend_from_slice(&self.2.to_le_bytes());
    }

    fn decode(b: &[u8]) -> Self {
        (get_u64(b, 0), get_u64(b, 8), get_u64(b, 16))
    }
}

/// Column view of a fixed-width record, for the delta+varint compressed
/// blocks the v5 preprocessed store writes (see [`compress_columnar`]).
/// Each record exposes `COLUMNS` `u64` columns; narrower fields (the
/// `u32` op id) widen losslessly.
pub trait ColumnarCodec: SegmentCodec {
    const COLUMNS: usize;
    /// Column `c` of this record as a `u64` (`c < COLUMNS`).
    fn column(&self, c: usize) -> u64;
    /// Rebuild a record from its `COLUMNS` column values.
    fn from_columns(cols: &[u64]) -> Self;
}

impl ColumnarCodec for ProvTriple {
    const COLUMNS: usize = 3;

    fn column(&self, c: usize) -> u64 {
        match c {
            0 => self.src.raw(),
            1 => self.dst.raw(),
            _ => u64::from(self.op.0),
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        ProvTriple::new(AttrValueId(cols[0]), AttrValueId(cols[1]), OpId(cols[2] as u32))
    }
}

impl ColumnarCodec for CcTriple {
    const COLUMNS: usize = 4;

    fn column(&self, c: usize) -> u64 {
        if c < 3 {
            self.triple.column(c)
        } else {
            self.ccid.0
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        CcTriple { triple: ProvTriple::from_columns(&cols[..3]), ccid: ComponentId(cols[3]) }
    }
}

impl ColumnarCodec for CsTriple {
    const COLUMNS: usize = 5;

    fn column(&self, c: usize) -> u64 {
        match c {
            0..=2 => self.triple.column(c),
            3 => self.src_csid.0,
            _ => self.dst_csid.0,
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        CsTriple {
            triple: ProvTriple::from_columns(&cols[..3]),
            src_csid: SetId(cols[3]),
            dst_csid: SetId(cols[4]),
        }
    }
}

impl ColumnarCodec for SetDep {
    const COLUMNS: usize = 2;

    fn column(&self, c: usize) -> u64 {
        if c == 0 {
            self.src_csid.0
        } else {
            self.dst_csid.0
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        SetDep { src_csid: SetId(cols[0]), dst_csid: SetId(cols[1]) }
    }
}

impl ColumnarCodec for (u64, u64) {
    const COLUMNS: usize = 2;

    fn column(&self, c: usize) -> u64 {
        if c == 0 {
            self.0
        } else {
            self.1
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        (cols[0], cols[1])
    }
}

impl ColumnarCodec for (u64, u64, u64) {
    const COLUMNS: usize = 3;

    fn column(&self, c: usize) -> u64 {
        match c {
            0 => self.0,
            1 => self.1,
            _ => self.2,
        }
    }

    fn from_columns(cols: &[u64]) -> Self {
        (cols[0], cols[1], cols[2])
    }
}

#[inline]
fn zigzag(d: i64) -> u64 {
    ((d << 1) ^ (d >> 63)) as u64
}

#[inline]
fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn read_varint(b: &[u8], at: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = b.get(*at) else {
            bail!("varint runs past the end of the block");
        };
        *at += 1;
        if shift == 63 && byte & 0xfe != 0 {
            bail!("varint overflows u64: corrupt block");
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Delta+varint compress `rows`, column by column: within each column,
/// values are delta-encoded against the previous row (wrapping), zigzag-
/// mapped and written as LEB128 varints, columns back-to-back. Runs of
/// nearby ids — which is what a sorted partition holds — collapse to one
/// byte per value. The block is self-delimiting given the row count.
pub fn compress_columnar<T: ColumnarCodec>(rows: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * T::COLUMNS);
    for c in 0..T::COLUMNS {
        let mut prev = 0u64;
        for r in rows {
            let v = r.column(c);
            write_varint(&mut out, zigzag(v.wrapping_sub(prev) as i64));
            prev = v;
        }
    }
    out
}

/// Decode a [`compress_columnar`] block of exactly `rows` records.
/// Corrupt or truncated blocks come back as errors, never panics: the
/// minimum plausible size is checked before any allocation, every varint
/// is bounds-checked, and the block must be consumed exactly.
pub fn decompress_columnar<T: ColumnarCodec>(bytes: &[u8], rows: usize) -> Result<Vec<T>> {
    if bytes.len() < rows.saturating_mul(T::COLUMNS) {
        bail!(
            "compressed block of {} bytes cannot hold {rows} rows × {} columns: \
             corrupt or truncated",
            bytes.len(),
            T::COLUMNS
        );
    }
    let mut cols: Vec<Vec<u64>> = Vec::with_capacity(T::COLUMNS);
    let mut at = 0usize;
    for c in 0..T::COLUMNS {
        let mut col = Vec::with_capacity(rows);
        let mut prev = 0u64;
        for r in 0..rows {
            let z = read_varint(bytes, &mut at)
                .with_context(|| format!("decoding row {r} of column {c}"))?;
            let v = prev.wrapping_add(unzigzag(z) as u64);
            col.push(v);
            prev = v;
        }
        cols.push(col);
    }
    if at != bytes.len() {
        bail!(
            "{} trailing bytes after {rows} rows × {} columns: corrupt block",
            bytes.len() - at,
            T::COLUMNS
        );
    }
    let mut scratch = vec![0u64; T::COLUMNS];
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        for (s, col) in scratch.iter_mut().zip(&cols) {
            *s = col[r];
        }
        out.push(T::from_columns(&scratch));
    }
    Ok(out)
}

/// Write `parts` as one segment file at `path` (one segment per
/// partition, empty partitions included so indexes line up). Returns the
/// payload bytes written — what a spill reports as `bytes_spilled`.
pub fn write_segments<T: SegmentCodec>(path: &Path, parts: &[&[T]]) -> Result<u64> {
    write_segments_inner(path, parts)
        .with_context(|| format!("writing segment file {path:?}"))
}

fn write_segments_inner<T: SegmentCodec>(path: &Path, parts: &[&[T]]) -> Result<u64> {
    io_probe(FaultSite::SegmentIo)?;
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_SEG)?;
    w.write_all(&(T::RECORD_BYTES as u64).to_le_bytes())?;
    w.write_all(&(parts.len() as u64).to_le_bytes())?;
    for p in parts {
        w.write_all(&(p.len() as u64).to_le_bytes())?;
    }
    let mut buf = Vec::with_capacity(64 * 1024);
    let mut payload = 0u64;
    for p in parts {
        buf.clear();
        for r in *p {
            r.encode(&mut buf);
        }
        payload += buf.len() as u64;
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(payload)
}

/// An open segment file: header + directory in memory, payload on disk.
/// Cheap to clone behind an `Arc`; every [`read_segment`] opens, seeks and
/// reads independently, so concurrent readers never contend on a shared
/// file handle.
///
/// [`read_segment`]: Self::read_segment
#[derive(Debug)]
pub struct SegmentFile {
    path: PathBuf,
    record_bytes: u64,
    /// Absolute payload offset of each segment.
    offsets: Vec<u64>,
    /// Row count of each segment.
    rows: Vec<u64>,
}

impl SegmentFile {
    /// Open and validate a segment file: reads only the header/directory,
    /// checks every segment lies inside the file. Errors name the path.
    pub fn open(path: &Path) -> Result<Arc<Self>> {
        Self::open_inner(path).with_context(|| format!("opening segment file {path:?}"))
    }

    fn open_inner(path: &Path) -> Result<Arc<Self>> {
        io_probe(FaultSite::SegmentIo)?;
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut head = [0u8; 24];
        f.read_exact(&mut head).context("read header")?;
        if &head[..8] != MAGIC_SEG {
            bail!("not a provspark segment file (bad magic)");
        }
        let record_bytes = get_u64(&head, 8);
        let seg_count = get_u64(&head, 16);
        if record_bytes == 0 {
            bail!("corrupt header: zero record size");
        }
        // The directory itself must fit before any count is trusted.
        let dir_bytes = seg_count
            .checked_mul(8)
            .filter(|d| 24 + d <= file_len)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "segment count {seg_count} is implausible for a {file_len}-byte file: \
                     corrupt or truncated header"
                )
            })?;
        let mut dir = vec![0u8; dir_bytes as usize];
        f.read_exact(&mut dir).context("read segment directory")?;
        let mut offsets = Vec::with_capacity(seg_count as usize);
        let mut rows = Vec::with_capacity(seg_count as usize);
        let mut at = 24 + dir_bytes;
        for i in 0..seg_count as usize {
            let n = get_u64(&dir, i * 8);
            let bytes = n.checked_mul(record_bytes).ok_or_else(|| {
                anyhow::anyhow!("segment {i} row count {n} overflows: corrupt directory")
            })?;
            offsets.push(at);
            rows.push(n);
            at = at.checked_add(bytes).filter(|&end| end <= file_len).ok_or_else(|| {
                anyhow::anyhow!(
                    "segment {i} ({n} rows × {record_bytes} bytes at offset {at}) \
                     exceeds the {file_len}-byte file: corrupt or truncated"
                )
            })?;
        }
        Ok(Arc::new(Self { path: path.to_path_buf(), record_bytes, offsets, rows }))
    }

    pub fn segments(&self) -> usize {
        self.rows.len()
    }

    /// Row count of segment `i` (from the directory — no IO).
    pub fn rows(&self, i: usize) -> usize {
        self.rows[i] as usize
    }

    /// Payload bytes of segment `i`.
    pub fn bytes(&self, i: usize) -> u64 {
        self.rows[i] * self.record_bytes
    }

    /// Read and decode segment `i`: one seek, one sized read. Errors name
    /// the path and the segment.
    pub fn read_segment<T: SegmentCodec>(&self, i: usize) -> Result<Vec<T>> {
        self.read_segment_inner(i)
            .with_context(|| format!("reading segment {i} of {:?}", self.path))
    }

    fn read_segment_inner<T: SegmentCodec>(&self, i: usize) -> Result<Vec<T>> {
        io_probe(FaultSite::SegmentIo)?;
        if i >= self.rows.len() {
            bail!("segment index out of range ({} segments)", self.rows.len());
        }
        if T::RECORD_BYTES as u64 != self.record_bytes {
            bail!(
                "record size mismatch: file has {}-byte records, caller expects {}",
                self.record_bytes,
                T::RECORD_BYTES
            );
        }
        let n = self.rows[i] as usize;
        let mut f = std::fs::File::open(&self.path)?;
        f.seek(SeekFrom::Start(self.offsets[i]))?;
        let mut buf = vec![0u8; n * T::RECORD_BYTES];
        f.read_exact(&mut buf).context("read segment payload")?;
        Ok(buf.chunks_exact(T::RECORD_BYTES).map(T::decode).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::EntityId;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("provspark_segment_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn triples(n: u64, salt: u64) -> Vec<ProvTriple> {
        (0..n)
            .map(|i| {
                ProvTriple::new(
                    AttrValueId::new(EntityId(1), i + salt),
                    AttrValueId::new(EntityId(2), i * 3 + salt),
                    OpId((i % 5) as u32),
                )
            })
            .collect()
    }

    #[test]
    fn roundtrip_per_segment_including_empty() {
        let p = tmp("round.seg");
        let parts = [triples(7, 0), vec![], triples(13, 100)];
        let views: Vec<&[ProvTriple]> = parts.iter().map(|v| v.as_slice()).collect();
        let payload = write_segments(&p, &views).unwrap();
        assert_eq!(payload, 20 * (7 + 13));
        let f = SegmentFile::open(&p).unwrap();
        assert_eq!(f.segments(), 3);
        for (i, part) in parts.iter().enumerate() {
            assert_eq!(f.rows(i), part.len());
            assert_eq!(f.read_segment::<ProvTriple>(i).unwrap(), *part);
        }
    }

    #[test]
    fn every_codec_roundtrips() {
        let p = tmp("codecs.seg");
        let cc: Vec<CcTriple> = triples(5, 0)
            .into_iter()
            .map(|t| CcTriple { triple: t, ccid: ComponentId(t.dst.raw() % 3) })
            .collect();
        write_segments(&p, &[cc.as_slice()]).unwrap();
        assert_eq!(SegmentFile::open(&p).unwrap().read_segment::<CcTriple>(0).unwrap(), cc);

        let cs: Vec<CsTriple> = triples(5, 9)
            .into_iter()
            .map(|t| CsTriple { triple: t, src_csid: SetId(1), dst_csid: SetId(2) })
            .collect();
        write_segments(&p, &[cs.as_slice()]).unwrap();
        assert_eq!(SegmentFile::open(&p).unwrap().read_segment::<CsTriple>(0).unwrap(), cs);

        let deps = vec![SetDep { src_csid: SetId(3), dst_csid: SetId(4) }];
        write_segments(&p, &[deps.as_slice()]).unwrap();
        assert_eq!(SegmentFile::open(&p).unwrap().read_segment::<SetDep>(0).unwrap(), deps);

        let pairs = vec![(1u64, 2u64), (3, 4)];
        write_segments(&p, &[pairs.as_slice()]).unwrap();
        assert_eq!(
            SegmentFile::open(&p).unwrap().read_segment::<(u64, u64)>(0).unwrap(),
            pairs
        );
    }

    #[test]
    fn truncated_and_corrupt_files_name_the_path() {
        // Directory promises more rows than the file holds.
        let p = tmp("truncated.seg");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKSEG1");
        bytes.extend_from_slice(&20u64.to_le_bytes()); // record_bytes
        bytes.extend_from_slice(&1u64.to_le_bytes()); // seg_count
        bytes.extend_from_slice(&1000u64.to_le_bytes()); // rows, but no payload
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", SegmentFile::open(&p).unwrap_err());
        assert!(
            err.contains("truncated.seg") && err.contains("exceeds"),
            "error must name the path and the overrun: {err}"
        );

        // Implausible segment count (u64::MAX would overflow the directory).
        let p = tmp("huge_count.seg");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PSPKSEG1");
        bytes.extend_from_slice(&20u64.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", SegmentFile::open(&p).unwrap_err());
        assert!(
            err.contains("huge_count.seg") && err.contains("implausible"),
            "error must name the path: {err}"
        );

        // Wrong magic.
        let p = tmp("bad_magic.seg");
        std::fs::write(&p, b"NOTSEG!!rest").unwrap();
        let err = format!("{:#}", SegmentFile::open(&p).unwrap_err());
        assert!(err.contains("bad_magic.seg") && err.contains("magic"));

        // Record-size mismatch caught before any payload read.
        let p = tmp("mismatch.seg");
        let deps = vec![SetDep { src_csid: SetId(1), dst_csid: SetId(2) }];
        write_segments(&p, &[deps.as_slice()]).unwrap();
        let f = SegmentFile::open(&p).unwrap();
        let err = format!("{:#}", f.read_segment::<ProvTriple>(0).unwrap_err());
        assert!(err.contains("mismatch.seg") && err.contains("record size mismatch"));
    }

    #[test]
    fn columnar_roundtrips_every_type_including_empty_and_unsorted() {
        let trip = triples(9, 3);
        assert_eq!(
            decompress_columnar::<ProvTriple>(&compress_columnar(&trip), trip.len()).unwrap(),
            trip
        );
        let cc: Vec<CcTriple> = triples(7, 0)
            .into_iter()
            .rev() // deliberately unsorted
            .map(|t| CcTriple { triple: t, ccid: ComponentId(t.dst.raw() % 3) })
            .collect();
        assert_eq!(decompress_columnar::<CcTriple>(&compress_columnar(&cc), cc.len()).unwrap(), cc);
        let cs: Vec<CsTriple> = triples(7, 5)
            .into_iter()
            .map(|t| CsTriple { triple: t, src_csid: SetId(t.src.raw()), dst_csid: SetId(2) })
            .collect();
        assert_eq!(decompress_columnar::<CsTriple>(&compress_columnar(&cs), cs.len()).unwrap(), cs);
        let deps = vec![SetDep { src_csid: SetId(u64::MAX), dst_csid: SetId(0) }];
        assert_eq!(
            decompress_columnar::<SetDep>(&compress_columnar(&deps), deps.len()).unwrap(),
            deps
        );
        let pairs = vec![(u64::MAX, 0u64), (0, u64::MAX), (5, 5)];
        assert_eq!(
            decompress_columnar::<(u64, u64)>(&compress_columnar(&pairs), pairs.len()).unwrap(),
            pairs
        );
        let wide = vec![(1u64, 2u64, 3u64), (4, 5, 6)];
        assert_eq!(
            decompress_columnar::<(u64, u64, u64)>(&compress_columnar(&wide), wide.len())
                .unwrap(),
            wide
        );
        // The empty block is the empty byte string.
        let empty: Vec<ProvTriple> = Vec::new();
        assert!(compress_columnar(&empty).is_empty());
        assert!(decompress_columnar::<ProvTriple>(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn columnar_blocks_beat_raw_records_on_sorted_ids() {
        let mut rows = triples(500, 0);
        rows.sort_by_key(|t| (t.dst.raw(), t.src.raw()));
        let block = compress_columnar(&rows);
        let raw = rows.len() * ProvTriple::RECORD_BYTES;
        assert!(
            block.len() * 2 < raw,
            "sorted ids must compress at least 2x: {} vs {raw}",
            block.len()
        );
    }

    #[test]
    fn corrupt_columnar_blocks_are_errors_not_panics() {
        let rows = triples(20, 0);
        let block = compress_columnar(&rows);
        // Truncated mid-column.
        let err = format!(
            "{:#}",
            decompress_columnar::<ProvTriple>(&block[..block.len() - 1], rows.len())
                .unwrap_err()
        );
        assert!(err.contains("column"), "truncation must name the column: {err}");
        // Trailing garbage after a complete block.
        let mut padded = block.clone();
        padded.push(0);
        let err = format!(
            "{:#}",
            decompress_columnar::<ProvTriple>(&padded, rows.len()).unwrap_err()
        );
        assert!(err.contains("trailing"), "expected a trailing-bytes error: {err}");
        // A varint that never terminates within u64 range.
        let err = format!(
            "{:#}",
            decompress_columnar::<SetDep>(&[0xff; 64], 2).unwrap_err()
        );
        assert!(err.contains("overflows"), "expected a varint-overflow error: {err}");
        // A block far too small for the claimed row count must error before
        // any row-count-sized allocation.
        let err = format!(
            "{:#}",
            decompress_columnar::<ProvTriple>(&[0u8; 4], usize::MAX).unwrap_err()
        );
        assert!(err.contains("cannot hold"), "expected a plausibility error: {err}");
    }

    #[test]
    fn injected_segment_io_faults_surface_as_errors() {
        use crate::fault::{install_io_faults, FaultInjector, FaultPlan};
        let p = tmp("faulted.seg");
        let rows = triples(4, 0);
        write_segments(&p, &[rows.as_slice()]).unwrap();
        let plan: FaultPlan = "io:segment:1.0,seed=4".parse().unwrap();
        install_io_faults(Some(Arc::new(FaultInjector::new(plan))));
        let open_err = format!("{:#}", SegmentFile::open(&p).unwrap_err());
        install_io_faults(None);
        assert!(open_err.contains("injected"), "expected the injected fault: {open_err}");
        // With the plan removed the same file reads fine.
        let f = SegmentFile::open(&p).unwrap();
        assert_eq!(f.read_segment::<ProvTriple>(0).unwrap(), rows);
        // And a read-side fault surfaces there too, naming the segment.
        let plan: FaultPlan = "io:segment:1.0,seed=4".parse().unwrap();
        install_io_faults(Some(Arc::new(FaultInjector::new(plan))));
        let read_err = format!("{:#}", f.read_segment::<ProvTriple>(0).unwrap_err());
        install_io_faults(None);
        assert!(read_err.contains("faulted.seg") && read_err.contains("injected"));
    }
}
