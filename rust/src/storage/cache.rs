//! The byte-budgeted partition cache: LRU over decoded segments, with pin
//! counts so in-flight scans are unevictable.
//!
//! One cache serves every paged dataset of a
//! [`MiniSpark`](crate::minispark::MiniSpark) context. Entries are keyed
//! `(file id, segment index)` — file ids are handed out by
//! [`register_file`](PartitionCache::register_file) so two spilled
//! datasets can never collide — and hold the decoded rows as
//! `Arc<Vec<T>>` behind `dyn Any` (one key always maps to one row type,
//! enforced by the issuing dataset).
//!
//! Eviction drops only the cache's own `Arc`; the segment file remains on
//! disk and a later fetch decodes it again. That makes the cache purely a
//! performance layer: with any budget, including one too small for a
//! single partition, answers are identical to the unbounded path.

use crate::minispark::EngineMetrics;
use anyhow::Result;
use rustc_hash::FxHashMap;
use std::any::Any;
use std::collections::hash_map::Entry as MapEntry;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A cached, decoded partition.
struct Slot {
    data: Arc<dyn Any + Send + Sync>,
    bytes: u64,
    /// Fetches in flight: entries with `pins > 0` are never evicted.
    pins: u32,
    /// LRU clock value of the last fetch.
    last_used: u64,
    /// Loaded by readahead and not yet claimed by a demand fetch: the
    /// first demand hit on this entry counts as a `prefetch_hit`.
    prefetched: bool,
}

/// How a fetch is attributed in the metrics: a [`Demand`](Self::Demand)
/// fetch sits on the query's critical path (hits and misses count, and a
/// hit on a still-warm prefetched entry counts as a `prefetch_hit`); a
/// [`Prefetch`](Self::Prefetch) fetch runs off the critical path (its IO
/// volume counts, but it is neither a cache hit nor a cache miss, and the
/// entry it loads is marked prefetched).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchKind {
    Demand,
    Prefetch,
}

#[derive(Default)]
struct Inner {
    map: FxHashMap<(u64, u32), Slot>,
    /// Monotone fetch clock (recency order for eviction).
    tick: u64,
    resident_bytes: u64,
}

/// Byte-budgeted LRU cache of decoded partitions (see module docs).
///
/// `budget == 0` means unbounded: nothing is ever evicted.
pub struct PartitionCache {
    budget: u64,
    metrics: Arc<EngineMetrics>,
    next_file: AtomicU64,
    inner: Mutex<Inner>,
}

impl PartitionCache {
    /// A cache with its own private metrics (tests / standalone use).
    pub fn new(budget: u64) -> Self {
        Self::with_metrics(budget, Arc::new(EngineMetrics::default()))
    }

    /// A cache reporting hits/misses/evictions/paging volume into shared
    /// engine metrics — how `MiniSpark` constructs its cache.
    pub fn with_metrics(budget: u64, metrics: Arc<EngineMetrics>) -> Self {
        Self {
            budget,
            metrics,
            next_file: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// The configured memory budget in bytes (0 = unbounded).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// The metrics sink this cache reports into.
    pub fn metrics(&self) -> &Arc<EngineMetrics> {
        &self.metrics
    }

    /// Allocate a fresh file id: the namespace one spilled dataset's
    /// segments live under.
    pub fn register_file(&self) -> u64 {
        self.next_file.fetch_add(1, Ordering::Relaxed)
    }

    /// Bytes currently resident (decoded rows owned by the cache).
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident_bytes
    }

    /// Number of partitions currently resident.
    pub fn resident_partitions(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Record segment bytes written by a spill (observability only — the
    /// spill itself happens in the dataset layer).
    pub fn note_spilled(&self, bytes: u64) {
        self.metrics.add_bytes_spilled(bytes);
    }

    /// Fetch `(file, seg)`, loading and decoding it via `load` on a miss.
    /// Returns the rows, whether this was a hit, and a [`PinGuard`] that
    /// keeps the entry unevictable until dropped.
    ///
    /// For sources whose on-disk size equals the decoded size — see
    /// [`get_or_load_sized`](Self::get_or_load_sized) for the compressed
    /// path and the exact accounting.
    pub fn get_or_load<T: Send + Sync + 'static>(
        self: &Arc<Self>,
        file: u64,
        seg: u32,
        load: impl FnOnce() -> Result<Vec<T>>,
    ) -> Result<(Arc<Vec<T>>, bool, PinGuard)> {
        self.get_or_load_sized(file, seg, FetchKind::Demand, || {
            let rows = load()?;
            let disk = (rows.len() * std::mem::size_of::<T>()) as u64;
            Ok((rows, disk))
        })
    }

    /// [`get_or_load`](Self::get_or_load) for sources whose on-disk size
    /// differs from the decoded size (compressed v5 blocks): the loader
    /// returns `(rows, disk_bytes)`. The budget and
    /// [`resident_bytes`](Self::resident_bytes) charge the **decoded**
    /// in-memory size — that is what competes for RAM — while
    /// `bytes_paged_in` charges the on-disk bytes actually read and
    /// `bytes_decoded` the decoded volume, so compression shows up as the
    /// gap between the two.
    ///
    /// The loader runs *outside* the cache lock, so slow segment IO never
    /// serializes unrelated lookups. Two threads racing on the same cold
    /// segment may both decode it (both observe a miss); the first insert
    /// wins the cache slot and both results are valid reads of the same
    /// immutable segment.
    pub fn get_or_load_sized<T: Send + Sync + 'static>(
        self: &Arc<Self>,
        file: u64,
        seg: u32,
        kind: FetchKind,
        load: impl FnOnce() -> Result<(Vec<T>, u64)>,
    ) -> Result<(Arc<Vec<T>>, bool, PinGuard)> {
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.map.get_mut(&(file, seg)) {
                e.pins += 1;
                e.last_used = tick;
                let served_prefetch = e.prefetched && kind == FetchKind::Demand;
                if served_prefetch {
                    e.prefetched = false; // a warmed page pays out once
                }
                let data = Arc::clone(&e.data)
                    .downcast::<Vec<T>>()
                    .expect("partition cache key maps to a different row type");
                drop(g);
                if kind == FetchKind::Demand {
                    self.metrics.add_cache_hit();
                    if served_prefetch {
                        self.metrics.add_prefetch_hit();
                    }
                }
                return Ok((data, true, PinGuard::new(self, file, seg)));
            }
        }
        let (rows, disk_bytes) = load()?;
        let data = Arc::new(rows);
        let bytes = (data.len() * std::mem::size_of::<T>()) as u64;
        if kind == FetchKind::Demand {
            self.metrics.add_cache_miss();
        }
        self.metrics.add_bytes_paged_in(disk_bytes);
        self.metrics.add_bytes_decoded(bytes);
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        match g.map.entry((file, seg)) {
            MapEntry::Occupied(mut o) => {
                // Lost a load race; pin the winner's entry, serve our copy.
                let e = o.get_mut();
                e.pins += 1;
                e.last_used = tick;
            }
            MapEntry::Vacant(v) => {
                v.insert(Slot {
                    data: Arc::clone(&data) as Arc<dyn Any + Send + Sync>,
                    bytes,
                    pins: 1,
                    last_used: tick,
                    prefetched: kind == FetchKind::Prefetch,
                });
                g.resident_bytes += bytes;
                self.evict_locked(&mut g);
            }
        }
        drop(g);
        Ok((data, false, PinGuard::new(self, file, seg)))
    }

    /// Whether `(file, seg)` is resident right now — no pin taken, no
    /// metrics touched. The readahead planner's cheap pre-check.
    pub fn contains(&self, file: u64, seg: u32) -> bool {
        self.inner.lock().unwrap().map.contains_key(&(file, seg))
    }

    /// Warm-insert a partition the caller already holds (a fresh spill):
    /// unpinned, immediately subject to the budget. Neither a hit nor a
    /// miss — no IO happened.
    pub fn admit<T: Send + Sync + 'static>(&self, file: u64, seg: u32, data: Arc<Vec<T>>) {
        let bytes = (data.len() * std::mem::size_of::<T>()) as u64;
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let MapEntry::Vacant(v) = g.map.entry((file, seg)) {
            v.insert(Slot {
                data: data as Arc<dyn Any + Send + Sync>,
                bytes,
                pins: 0,
                last_used: tick,
                prefetched: false,
            });
            g.resident_bytes += bytes;
            self.evict_locked(&mut g);
        }
    }

    fn unpin(&self, file: u64, seg: u32) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.map.get_mut(&(file, seg)) {
            e.pins = e.pins.saturating_sub(1);
        }
        // A wide scan can pin past the budget; trim as pins release.
        self.evict_locked(&mut g);
    }

    /// Evict least-recently-used unpinned entries until the budget holds.
    /// Entries still referenced by in-flight `Arc`s free their memory only
    /// when those readers finish — the accounting tracks what the *cache*
    /// owns, which is the quantity the budget governs.
    fn evict_locked(&self, g: &mut Inner) {
        if self.budget == 0 {
            return;
        }
        while g.resident_bytes > self.budget {
            let victim = g
                .map
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k);
            let Some(k) = victim else { break };
            let e = g.map.remove(&k).expect("victim vanished under the lock");
            g.resident_bytes -= e.bytes;
            self.metrics.add_eviction();
        }
    }
}

impl std::fmt::Debug for PartitionCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("PartitionCache")
            .field("budget", &self.budget)
            .field("resident_bytes", &g.resident_bytes)
            .field("resident_partitions", &g.map.len())
            .finish()
    }
}

/// Keeps one cache entry pinned (unevictable) until dropped — handed out
/// by [`PartitionCache::get_or_load`] and held for the duration of a scan.
pub struct PinGuard {
    cache: Arc<PartitionCache>,
    file: u64,
    seg: u32,
}

impl PinGuard {
    fn new(cache: &Arc<PartitionCache>, file: u64, seg: u32) -> Self {
        Self { cache: Arc::clone(cache), file, seg }
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.cache.unpin(self.file, self.seg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, tag: u64) -> Vec<u64> {
        (0..n as u64).map(|i| i ^ tag).collect()
    }

    #[test]
    fn hit_miss_and_paged_bytes_are_counted() {
        let c = Arc::new(PartitionCache::new(0));
        let f = c.register_file();
        let (a, hit, _p) = c.get_or_load(f, 0, || Ok(rows(10, 1))).unwrap();
        assert!(!hit);
        let (b, hit, _p2) = c.get_or_load(f, 0, || panic!("must not reload")).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
        let m = c.metrics().snapshot();
        assert_eq!((m.cache_hits, m.cache_misses), (1, 1));
        assert_eq!(m.bytes_paged_in, 10 * 8);
        assert_eq!(m.evictions, 0);
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        // Budget fits exactly two 80-byte partitions.
        let c = Arc::new(PartitionCache::new(160));
        let f = c.register_file();
        c.get_or_load(f, 0, || Ok(rows(10, 0))).unwrap();
        c.get_or_load(f, 1, || Ok(rows(10, 1))).unwrap();
        // Touch 0 so 1 becomes the LRU victim.
        let (_, hit, _p) = c.get_or_load(f, 0, || unreachable!()).unwrap();
        assert!(hit);
        drop(_p);
        c.get_or_load(f, 2, || Ok(rows(10, 2))).unwrap();
        assert_eq!(c.resident_partitions(), 2);
        // 1 was evicted; 0 survived.
        let (_, hit, _p) = c.get_or_load(f, 0, || unreachable!()).unwrap();
        assert!(hit, "recently-used entry must survive");
        let (_, hit, _p) = c.get_or_load(f, 1, || Ok(rows(10, 1))).unwrap();
        assert!(!hit, "LRU entry must have been evicted");
        assert_eq!(c.metrics().snapshot().evictions, 2);
    }

    #[test]
    fn pinned_entries_survive_a_budget_overshoot() {
        // Budget of one partition; pin two at once (a 2-partition scan).
        let c = Arc::new(PartitionCache::new(80));
        let f = c.register_file();
        let (_, _, pin0) = c.get_or_load(f, 0, || Ok(rows(10, 0))).unwrap();
        let (_, _, pin1) = c.get_or_load(f, 1, || Ok(rows(10, 1))).unwrap();
        // Both pinned: over budget but nothing evictable.
        assert_eq!(c.resident_partitions(), 2);
        assert!(c.resident_bytes() > c.budget());
        drop(pin0);
        drop(pin1);
        // Pins released: trimmed back under budget.
        assert_eq!(c.resident_partitions(), 1);
        assert!(c.resident_bytes() <= c.budget());
    }

    #[test]
    fn distinct_files_never_collide() {
        let c = Arc::new(PartitionCache::new(0));
        let (f1, f2) = (c.register_file(), c.register_file());
        assert_ne!(f1, f2);
        let (a, _, _p) = c.get_or_load(f1, 0, || Ok(rows(3, 7))).unwrap();
        let (b, _, _q) = c.get_or_load(f2, 0, || Ok(rows(4, 9))).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn admit_is_warm_and_budgeted() {
        let c = Arc::new(PartitionCache::new(80));
        let f = c.register_file();
        c.admit(f, 0, Arc::new(rows(10, 0)));
        c.admit(f, 1, Arc::new(rows(10, 1)));
        // Second admit evicted the first (budget = one partition).
        assert_eq!(c.resident_partitions(), 1);
        let m = c.metrics().snapshot();
        assert_eq!((m.cache_hits, m.cache_misses), (0, 0), "admit is not a fetch");
        assert_eq!(m.evictions, 1);
        let (_, hit, _p) = c.get_or_load(f, 1, || unreachable!()).unwrap();
        assert!(hit, "admitted entry serves the first fetch warm");
    }

    #[test]
    fn sized_loads_charge_disk_and_decoded_separately() {
        let c = Arc::new(PartitionCache::new(0));
        let f = c.register_file();
        // 10 decoded u64 rows (80 bytes) from a 16-byte compressed read.
        let (_, hit, _p) = c
            .get_or_load_sized(f, 0, FetchKind::Demand, || Ok((rows(10, 1), 16)))
            .unwrap();
        assert!(!hit);
        let m = c.metrics().snapshot();
        assert_eq!(m.bytes_paged_in, 16, "paged-in charges the on-disk size");
        assert_eq!(m.bytes_decoded, 80, "decoded charges the in-memory size");
        assert_eq!(c.resident_bytes(), 80, "the budget governs decoded bytes");
    }

    #[test]
    fn prefetch_loads_are_not_misses_and_pay_out_one_hit() {
        let c = Arc::new(PartitionCache::new(0));
        let f = c.register_file();
        assert!(!c.contains(f, 0));
        let (_, hit, _p) = c
            .get_or_load_sized(f, 0, FetchKind::Prefetch, || Ok((rows(10, 1), 80)))
            .unwrap();
        assert!(!hit);
        assert!(c.contains(f, 0));
        let m = c.metrics().snapshot();
        assert_eq!(
            (m.cache_hits, m.cache_misses),
            (0, 0),
            "prefetch stays off the demand counters"
        );
        assert_eq!(m.bytes_paged_in, 80, "but its IO volume is real");
        // First demand fetch: a hit, attributed to the prefetch.
        let (_, hit, _q) = c.get_or_load::<u64>(f, 0, || unreachable!()).unwrap();
        assert!(hit);
        // Second demand fetch: a plain hit.
        let (_, hit, _r) = c.get_or_load::<u64>(f, 0, || unreachable!()).unwrap();
        assert!(hit);
        let m = c.metrics().snapshot();
        assert_eq!((m.cache_hits, m.cache_misses), (2, 0));
        assert_eq!(m.prefetch_hits, 1, "a warmed page pays out exactly once");
    }

    #[test]
    fn loader_errors_propagate_and_cache_stays_clean() {
        let c = Arc::new(PartitionCache::new(0));
        let f = c.register_file();
        let err = c
            .get_or_load::<u64>(f, 0, || anyhow::bail!("segment rotted"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("segment rotted"));
        assert_eq!(c.resident_partitions(), 0);
    }
}
