//! # storage — the out-of-core memory hierarchy
//!
//! Everything above this module computes over in-memory `Vec`s; everything
//! below the paper's 500M-edge regime requires more rows than RAM holds.
//! This module is the tier in between: a **segmented columnar store**
//! ([`segment`]) whose files carry a per-partition directory so any single
//! partition is readable with one seek (no whole-file deserialization),
//! and a **byte-budgeted partition cache** ([`cache`]) through which
//! [`Dataset`](crate::minispark::Dataset) lookups fault those segments in
//! on demand.
//!
//! The contract mirrors OS demand paging:
//!
//! * **Spill once, page forever.** A spilled dataset's segment file is
//!   immutable. Eviction merely drops the cache's `Arc` to the decoded
//!   rows; any in-flight scan still holding that `Arc` keeps its data, so
//!   eviction can never corrupt a running query.
//! * **Pin while scanning.** Fetching a partition pins its cache entry
//!   until the returned guard drops — a multi-partition BFS round never
//!   loses its own working set to the eviction it causes.
//! * **Budget is a target, not a ceiling.** Pinned entries are
//!   unevictable, so a scan wider than the budget transiently overshoots
//!   and the cache trims back down as pins release. Correctness is
//!   therefore independent of the budget — a pathologically tiny budget
//!   just thrashes.
//!
//! Two IO-shaping layers ride on that contract:
//!
//! * **Frontier-driven prefetch** ([`prefetch`]): a BFS frontier names the
//!   partitions the *next* round will fault a full round early, so engines
//!   hand that partition set to a background readahead pool that warms
//!   (and pins) the cache off the critical path. Prefetch is purely a
//!   performance layer — it is disabled under armed fault plans, by
//!   `PROVSPARK_PREFETCH=off`, or with `prefetch_depth = 0`, and answers
//!   never depend on it.
//! * **Compressed columnar blocks** ([`segment::compress_columnar`]): the
//!   v5 preprocessed store writes each partition as delta+varint column
//!   streams, trading decode CPU for the disk bytes that dominate paging.
//!
//! The cache reports `cache_hits` / `cache_misses` / `evictions` /
//! `bytes_spilled` / `bytes_paged_in` / `bytes_decoded` — plus
//! `prefetch_issued` / `prefetch_hits` — through the engine-wide
//! [`EngineMetrics`](crate::minispark::EngineMetrics), and per-query
//! attribution flows through [`ScanCost`](crate::minispark::ScanCost).
//! See `ARCHITECTURE.md` § "Memory hierarchy & segment store".

pub mod cache;
pub mod prefetch;
pub mod segment;

pub use cache::{FetchKind, PartitionCache, PinGuard};
pub use prefetch::{prefetch_enabled, PrefetchBatch, Prefetcher};
pub use segment::{
    compress_columnar, decompress_columnar, write_segments, ColumnarCodec, SegmentCodec,
    SegmentFile,
};
