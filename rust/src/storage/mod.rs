//! # storage — the out-of-core memory hierarchy
//!
//! Everything above this module computes over in-memory `Vec`s; everything
//! below the paper's 500M-edge regime requires more rows than RAM holds.
//! This module is the tier in between: a **segmented columnar store**
//! ([`segment`]) whose files carry a per-partition directory so any single
//! partition is readable with one seek (no whole-file deserialization),
//! and a **byte-budgeted partition cache** ([`cache`]) through which
//! [`Dataset`](crate::minispark::Dataset) lookups fault those segments in
//! on demand.
//!
//! The contract mirrors OS demand paging:
//!
//! * **Spill once, page forever.** A spilled dataset's segment file is
//!   immutable. Eviction merely drops the cache's `Arc` to the decoded
//!   rows; any in-flight scan still holding that `Arc` keeps its data, so
//!   eviction can never corrupt a running query.
//! * **Pin while scanning.** Fetching a partition pins its cache entry
//!   until the returned guard drops — a multi-partition BFS round never
//!   loses its own working set to the eviction it causes.
//! * **Budget is a target, not a ceiling.** Pinned entries are
//!   unevictable, so a scan wider than the budget transiently overshoots
//!   and the cache trims back down as pins release. Correctness is
//!   therefore independent of the budget — a pathologically tiny budget
//!   just thrashes.
//!
//! The cache reports `cache_hits` / `cache_misses` / `evictions` /
//! `bytes_spilled` / `bytes_paged_in` through the engine-wide
//! [`EngineMetrics`](crate::minispark::EngineMetrics), and per-query
//! attribution flows through [`ScanCost`](crate::minispark::ScanCost).
//! See `ARCHITECTURE.md` § "Memory hierarchy & segment store".

pub mod cache;
pub mod segment;

pub use cache::{PartitionCache, PinGuard};
pub use segment::{write_segments, SegmentCodec, SegmentFile};
