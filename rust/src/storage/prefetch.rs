//! Frontier-driven readahead: a tiny background pool that warms the
//! [`PartitionCache`](crate::storage::PartitionCache) ahead of the fault.
//!
//! At the end of a BFS round the engines already know — from the
//! [`HashPartitioner`](crate::minispark::HashPartitioner) keying — exactly
//! which partitions the *next* round's `multi_lookup` will touch. A
//! [`Prefetcher`] turns that free oracle into IO overlap: the dataset
//! layer enqueues one job per cold partition, a worker loads and decodes
//! it through the cache's prefetch path (counted as `prefetch_issued`,
//! never as a `cache_miss`), and parks the pin in the round's
//! [`PrefetchBatch`] so the page cannot be evicted before the round that
//! asked for it runs. When the demand lookup later hits the warmed entry,
//! the hit is attributed as a `prefetch_hit`.
//!
//! Prefetch is strictly a performance layer: answers are byte-identical
//! with it on, off (`PROVSPARK_PREFETCH=off`, or `prefetch_depth = 0`),
//! or racing — a job that loses its race simply finds the entry resident.
//! It is disabled entirely while a fault plan is armed, because the
//! deterministic fault sequences are defined over the *demand* IO order
//! and a background probe would consume their draws.

use crate::storage::cache::PinGuard;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Process-wide kill switch: `PROVSPARK_PREFETCH=off` disables every
/// prefetcher in the process (read once, cached).
pub fn prefetch_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !std::env::var("PROVSPARK_PREFETCH").is_ok_and(|v| v.eq_ignore_ascii_case("off"))
    })
}

/// One readahead unit: loads a partition through the cache and parks the
/// pin. Errors are swallowed inside the job — the demand path will retry
/// the IO and surface them with full context.
pub type Job = Box<dyn FnOnce() + Send>;

/// Readahead workers per context: enough to overlap decode with the
/// round's compute without contending with the task pool for cores.
const WORKER_THREADS: usize = 2;

struct Workers {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
}

/// A lazily-spawned background pool for readahead jobs. One per
/// [`MiniSpark`](crate::minispark::MiniSpark) context; dropping it closes
/// the queue and joins the workers, so no job outlives the context (or
/// its spill directory).
#[derive(Default)]
pub struct Prefetcher {
    workers: Mutex<Option<Workers>>,
}

impl Prefetcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue one readahead job. Worker threads spawn on first use, so
    /// contexts that never prefetch never pay for the pool.
    pub fn submit(&self, job: Job) {
        let mut g = self.workers.lock().unwrap();
        let w = g.get_or_insert_with(|| {
            let (tx, rx) = channel::<Job>();
            let rx = Arc::new(Mutex::new(rx));
            let handles = (0..WORKER_THREADS)
                .map(|i| {
                    let rx = Arc::clone(&rx);
                    std::thread::Builder::new()
                        .name(format!("provspark-prefetch-{i}"))
                        .spawn(move || worker_loop(&rx))
                        .expect("spawning a prefetch worker")
                })
                .collect();
            Workers { tx, handles }
        });
        // The receiver only disappears at shutdown; dropping the job then
        // is exactly the right behavior.
        let _ = w.tx.send(job);
    }
}

fn worker_loop(rx: &Arc<Mutex<Receiver<Job>>>) {
    loop {
        // Take the job with the lock released before running it, so one
        // slow decode never serializes the other worker.
        let job = rx.lock().unwrap().recv();
        match job {
            Ok(job) => job(),
            Err(_) => break, // queue closed: the Prefetcher is dropping
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        if let Some(w) = self.workers.lock().unwrap().take() {
            drop(w.tx); // close the queue; workers drain what's left and exit
            for h in w.handles {
                let _ = h.join();
            }
        }
    }
}

impl std::fmt::Debug for Prefetcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let spawned = self.workers.lock().unwrap().is_some();
        f.debug_struct("Prefetcher").field("spawned", &spawned).finish()
    }
}

/// The pins one round of readahead acquired. Hold it across the BFS round
/// the pages were fetched for, then drop (or overwrite) it: prefetched
/// partitions stay unevictable until their round has consumed them, and
/// release immediately after.
///
/// In-flight jobs share the sink through an `Arc`, so a pin pushed after
/// the batch dropped is released when the last job's handle goes away —
/// nothing leaks, nothing stays pinned past its round plus the job tail.
pub struct PrefetchBatch {
    pins: Arc<Mutex<Vec<PinGuard>>>,
}

impl PrefetchBatch {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { pins: Arc::new(Mutex::new(Vec::new())) }
    }

    /// The shared sink readahead jobs push their pins into.
    pub fn pin_sink(&self) -> Arc<Mutex<Vec<PinGuard>>> {
        Arc::clone(&self.pins)
    }
}

impl Drop for PrefetchBatch {
    fn drop(&mut self) {
        self.pins.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_and_shutdown_joins() {
        let p = Prefetcher::new();
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let n = Arc::clone(&n);
            p.submit(Box::new(move || {
                n.fetch_add(1, Ordering::SeqCst);
            }));
        }
        // Dropping joins the workers, so every queued job has run.
        drop(p);
        assert_eq!(n.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn batch_drop_releases_pins() {
        use crate::storage::cache::{FetchKind, PartitionCache};
        let c = Arc::new(PartitionCache::new(8)); // budget below one partition
        let f = c.register_file();
        let batch = PrefetchBatch::new();
        let sink = batch.pin_sink();
        let (_, _, pin) = c
            .get_or_load_sized(f, 0, FetchKind::Prefetch, || Ok((vec![1u64, 2], 4)))
            .unwrap();
        sink.lock().unwrap().push(pin);
        // Pinned by the batch: survives being over budget.
        assert_eq!(c.resident_partitions(), 1);
        drop(batch);
        // Pin released with the batch: the entry is evictable again.
        assert_eq!(c.resident_partitions(), 0);
    }
}
