//! provspark — CLI for the workflow-provenance query framework.
//!
//! ```text
//! provspark generate    --scale-divisor 10 --replication 1 --out data/trace.bin
//! provspark stats       --trace data/trace.bin
//! provspark preprocess  --trace data/trace.bin --out data/pre.bin [--wcc-impl driver|minispark|minispark-naive|xla]
//!                       [--shards N]  (also writes per-shard pre/trace files)
//! provspark ingest      --trace data/trace.bin --pre data/pre.bin --batch delta.bin
//!                       [--out-trace X --out-pre Y]  (defaults: update in place)
//!                       [--shards N]  (sharded scatter ingest with component migration)
//!                       [--retries N]  (journal-resume budget for interrupted migrations)
//! provspark query       --trace data/trace.bin --pre data/pre.bin --engine auto --item 3:42
//!                       [--item 3:43 ...] [--max-depth N] [--max-triples N] [--tau-override N]
//!                       [--deadline-ms N] [--retries N]  (deadline-bounded degraded answers)
//!                       [--shards N]  (scatter-gather across component-space shards)
//! provspark serve       --trace data/trace.bin --pre data/pre.bin [--shards N]
//!                       [--tenants N --requests N] [--window-ms N --window-max N]
//!                       [--queue-capacity N --quota-qps F --quota-burst F]
//!                       [--deadline-ms N] [--ingest-batches N]
//!                       (mixed-tenant serving front: admission, coalescing windows,
//!                        epoch-keyed result cache, streaming partial answers)
//! provspark classes     --trace data/trace.bin --pre data/pre.bin --class lc-ll
//! provspark table       --which 9|10|11|12 [--divisor 10] [--replications 1,9]
//! provspark drilldown   --trace data/trace.bin --pre data/pre.bin --item 3:42
//! provspark workflow    --dot
//! ```

use anyhow::{anyhow, bail, Context, Result};
use provspark::cli::Args;
use provspark::config::{Backend, EngineConfig};
use provspark::fault::{install_io_faults, FaultInjector, FaultPlan};
use provspark::harness::{
    component_census, drilldown_report, query_table, select_queries, table9, EngineRouter,
    ExperimentConfig, ProvSession, QueryClass, ShardedSession,
};
use provspark::minispark::MiniSpark;
use provspark::provenance::incremental::{IncrementalIndex, TripleBatch};
use provspark::provenance::journal::staged_path;
use provspark::provenance::pipeline::{preprocess, WccImpl};
use provspark::provenance::model::ProvTriple;
use provspark::provenance::query::{QueryOutcome, QueryRequest};
use provspark::provenance::store;
use provspark::serve::{ServeConfig, ServeFront};
use provspark::provenance::{commit_files, recover_commit, CommitRecovery, MigrationJournal};
use provspark::util::fmt::{human_count, human_duration};
use provspark::util::ids::{AttrValueId, OpId};
use provspark::workflow::curation::text_curation_workflow;
use provspark::workflow::generator::{generate, GeneratorConfig, TraceStats};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

const FLAGS: &[&str] = &["dot", "csv", "help", "verbose"];

fn main() {
    let args = match Args::parse_env(FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand().is_none() {
        print_help();
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "provspark — workflow provenance queries via weakly connected components/sets\n\
         subcommands: generate | stats | preprocess | ingest | query | serve | classes |\n\
                      table | drilldown | workflow\n\
         ingest opts: --trace FILE --pre FILE --batch FILE (a trace of new triples)\n\
                      [--out-trace FILE --out-pre FILE] — applies the delta incrementally\n\
                      (no full re-preprocess) and persists the updated index\n\
         common opts: --executors N --partitions N --job-overhead-us N --tau N --theta N\n\
                      --shuffle-elision true|false --wcc-backend native|xla\n\
                      --closure-backend native|xla --config FILE\n\
         memory:      --memory-budget BYTES (k/m/g suffixes; 0 = unbounded, the default) —\n\
                      engine datasets spill to segment files and partitions page back\n\
                      through a byte-budgeted LRU cache on demand; answers are identical\n\
                      under any budget. budgeted query sessions open segmented (v4/v5)\n\
                      index files zero-copy and demand-page only touched partitions.\n\
                      --prefetch-depth N caps the partitions each BFS round hands the\n\
                      background readahead pool (default 16, 0 = off; env\n\
                      PROVSPARK_PREFETCH=off is a global kill switch). preprocess\n\
                      --pre-partitions N sets the segmented index file's partition\n\
                      count (default 64; v5 = compressed columnar)\n\
         query opts:  --engine rq|ccprov|csprov|auto  --item ID (repeatable — batches fan\n\
                      out across the worker pool)  --max-depth N --max-triples N\n\
                      --tau-override N (per-query driver-collect threshold)\n\
                      --deadline-ms N (degrade past the budget: partial prefix lineage +\n\
                      completeness bound)  --retries N (per-item re-execution budget;\n\
                      failures are isolated, never batch-fatal)\n\
         serve opts:  --tenants N --requests N (per tenant; the last tenant runs\n\
                      deadline-bounded when --deadline-ms is given: partial prefix\n\
                      first, completed answer streamed second) --window-ms N\n\
                      --window-max N (micro-batch coalescing) --queue-capacity N\n\
                      --quota-qps F --quota-burst F (per-tenant token buckets; over-quota\n\
                      submits get typed rejections) --ingest-batches N (concurrent\n\
                      ingest; the result cache invalidates dirty components only)\n\
         sharding:    --shards N on preprocess/query/ingest — component-space shards\n\
                      behind a scatter-gather front (preprocess also writes per-shard\n\
                      files next to --out; ingest migrates components merged across\n\
                      shards and persists the gathered state)\n\
         resilience:  --fault-plan SPEC (deterministic injection, e.g.\n\
                      panic:shuffle:0.05,seed=6 or io:journal:@1 — sites\n\
                      task|shuffle|store|journal|segment)  --task-retries N\n\
                      --retry-backoff-us N (supervised in-job task retries)\n\
                      ingest --retries N resumes an interrupted sharded migration\n\
                      from its write-ahead journal; ingest publishes trace+index\n\
                      via staged files + a commit journal and self-recovers an\n\
                      interrupted publish on the next run"
    );
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    EngineConfig::from_sources(args.get("config"), args)
}

fn parse_item(s: &str) -> Result<u64> {
    if let Some((e, ser)) = s.split_once(':') {
        let e: u16 = e.parse().context("entity part")?;
        let ser: u64 = ser.parse().context("serial part")?;
        Ok(AttrValueId::new(provspark::util::ids::EntityId(e), ser).raw())
    } else {
        s.parse::<u64>().context("raw id")
    }
}

fn gen_config(args: &Args) -> Result<GeneratorConfig> {
    Ok(GeneratorConfig {
        seed: args.get_parsed_or("seed", GeneratorConfig::default().seed)?,
        scale_divisor: args.get_parsed_or("scale-divisor", 10)?,
        replication: args.get_parsed_or("replication", 1)?,
        extra_parent_prob: args.get_parsed_or("extra-parent-prob", 0.25)?,
    })
}

fn scaled_defaults(args: &Args, divisor: usize) -> Result<(usize, usize)> {
    let theta = args.get_parsed_or("theta", (25_000 / divisor).max(50))?;
    let big = args.get_parsed_or("big-threshold", (1000 / divisor).max(20))?;
    Ok((theta, big))
}

fn run(args: &Args) -> Result<()> {
    // `--fault-plan` reaches two layers: the cluster config (task/shuffle
    // probes inside minispark jobs, via `engine_config`) and this
    // thread-local installation, which arms the store/journal IO probes on
    // the CLI's own load/save paths.
    if let Some(spec) = args.get("fault-plan") {
        let plan: FaultPlan = spec.parse().context("--fault-plan")?;
        install_io_faults(Some(Arc::new(FaultInjector::new(plan))));
    }
    match args.subcommand().unwrap() {
        "generate" => {
            let cfg = gen_config(args)?;
            let out = args.get_or("out", "data/trace.bin");
            std::fs::create_dir_all(Path::new(&out).parent().unwrap_or(Path::new(".")))?;
            let ((trace, _, _), dur) = provspark::util::timer::time_it(|| generate(&cfg));
            store::save_trace(Path::new(&out), &trace)?;
            println!(
                "generated {} triples ({} nodes) in {} → {out}",
                human_count(trace.len() as u64),
                human_count(trace.node_count() as u64),
                human_duration(dur),
            );
            if args.has_flag("csv") {
                let csv = format!("{out}.csv");
                store::export_csv(Path::new(&csv), &trace)?;
                println!("csv export → {csv}");
            }
            Ok(())
        }
        "stats" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let divisor: usize = args.get_parsed_or("scale-divisor", 10)?;
            let (theta, _) = scaled_defaults(args, divisor)?;
            let (s, dur) =
                provspark::util::timer::time_it(|| TraceStats::compute(&trace, 20, theta));
            println!("{}", s.summary());
            println!("(computed in {})", human_duration(dur));
            Ok(())
        }
        "preprocess" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let out = args.get_or("out", "data/pre.bin");
            let divisor: usize = args.get_parsed_or("scale-divisor", 10)?;
            let (theta, big) = scaled_defaults(args, divisor)?;
            let ecfg = engine_config(args)?;
            let (g, splits) = text_curation_workflow();
            let default_impl = match ecfg.prov.wcc_backend {
                Backend::Native => "driver",
                Backend::Xla => "xla",
            };
            let wcc_impl_name = args.get_or("wcc-impl", default_impl);
            let sc = MiniSpark::new(ecfg.cluster.clone());
            let rt;
            let xla_fn;
            let wcc = match wcc_impl_name.as_str() {
                "driver" => WccImpl::Driver,
                "minispark" => {
                    WccImpl::MiniSpark { sc: &sc, partitions: ecfg.cluster.default_partitions }
                }
                "minispark-naive" => WccImpl::MiniSparkNaive {
                    sc: &sc,
                    partitions: ecfg.cluster.default_partitions,
                },
                "xla" => {
                    rt = provspark::runtime::XlaRuntime::new(Path::new(&ecfg.prov.artifact_dir))?;
                    xla_fn = move |t: &provspark::provenance::model::Trace| {
                        provspark::runtime::xla_wcc(&rt, t).expect("xla wcc")
                    };
                    WccImpl::Custom(&xla_fn)
                }
                other => {
                    bail!("unknown --wcc-impl {other:?} (driver|minispark|minispark-naive|xla)")
                }
            };
            let pre = preprocess(&trace, &g, &splits, theta, big, wcc);
            let pre_partitions: usize =
                args.get_parsed_or("pre-partitions", store::DEFAULT_PRE_PARTITIONS)?;
            store::save_preprocessed_with_partitions(Path::new(&out), &pre, pre_partitions)?;
            println!(
                "preprocessed: {} components ({} large), {} sets, {} set-deps",
                human_count(pre.component_count as u64),
                pre.large_components.len(),
                human_count(pre.set_count as u64),
                human_count(pre.set_deps.len() as u64),
            );
            for (name, d) in &pre.timings {
                println!("  {name:10} {}", human_duration(*d));
            }
            table9(&pre).print();
            component_census(&pre).print();
            println!("→ {out}");
            let shards: usize = args.get_parsed_or("shards", 1)?;
            if shards > 1 {
                // Split the index component-space and persist one
                // (trace, pre) pair per shard, headers recording the
                // position in the plan.
                let plan = provspark::provenance::shard::ShardPlan::new(shards);
                let asg = plan.assignment(&pre.cc_of);
                let shard_traces = trace.split_by_plan(&pre.cc_of, &asg)?;
                let shard_pres = pre.split_by_plan(&asg)?;
                for (i, (t, p)) in shard_traces.iter().zip(&shard_pres).enumerate() {
                    let pre_path = format!("{out}.shard{i}");
                    let trace_path = format!("{out}.shard{i}.trace");
                    store::save_preprocessed(Path::new(&pre_path), p)?;
                    store::save_trace(Path::new(&trace_path), t)?;
                    println!(
                        "shard {i}: {} triples, {} components ({} large), {} sets \
                         → {pre_path} (+ .trace)",
                        human_count(t.len() as u64),
                        human_count(p.component_count as u64),
                        p.large_components.len(),
                        human_count(p.set_count as u64),
                    );
                }
            }
            Ok(())
        }
        "ingest" => {
            let trace_path = args.get_or("trace", "data/trace.bin");
            let pre_path = args.get_or("pre", "data/pre.bin");
            let batch_path = args
                .get("batch")
                .ok_or_else(|| anyhow!("--batch required (a trace file of new triples)"))?;
            let out_trace = args.get_or("out-trace", &trace_path);
            let out_pre = args.get_or("out-pre", &pre_path);
            let finals = [PathBuf::from(&out_trace), PathBuf::from(&out_pre)];
            let publish_journal = PathBuf::from(format!("{out_pre}.publish-journal"));
            let migration_journal = PathBuf::from(format!("{out_pre}.migration-journal"));
            // Startup recovery, *before* anything loads: an interrupted
            // two-phase publish is rolled forward (journal durable ⇒
            // staging was complete) or its orphaned staged files discarded.
            match recover_commit(&publish_journal, &finals)? {
                CommitRecovery::Clean => {}
                CommitRecovery::RolledForward(n) => println!(
                    "recovered an interrupted publish: rolled {n} staged file(s) forward"
                ),
                CommitRecovery::RolledBack(n) => println!(
                    "recovered an interrupted publish: discarded {n} orphaned staged file(s)"
                ),
            }
            // A leftover migration journal means a sharded ingest died
            // mid-plan in a previous process. Stores are only rewritten
            // after a batch fully applies, so the on-disk state is the
            // pre-batch state: report, roll the journal back, re-ingest.
            if let Some(j) = MigrationJournal::load(&migration_journal)? {
                println!(
                    "found an interrupted sharded-ingest journal at {} ({}/{} steps \
                     committed); on-disk state is the pre-batch state — rolling back \
                     (this ingest starts the batch over)",
                    migration_journal.display(),
                    j.cursor(),
                    j.steps().len(),
                );
                std::fs::remove_file(&migration_journal).with_context(|| {
                    format!("rolling back {}", migration_journal.display())
                })?;
            }
            let trace = store::load_trace(Path::new(&trace_path))?;
            let pre = store::load_preprocessed(Path::new(&pre_path))?;
            let batch: TripleBatch = store::load_trace(Path::new(batch_path))?.into();
            let batch_len = batch.len();
            let retries: u32 = args.get_parsed_or("retries", 0)?;
            let shards: usize = args.get_parsed_or("shards", 1)?;
            if shards > 1 {
                // Sharded ingest: split component-space, route the batch
                // through the scatter front (migrating components merged
                // across shards), then gather and persist the combined
                // state.
                let ecfg = engine_config(args)?;
                let session = ShardedSession::new(&ecfg, Arc::new(trace), Arc::new(pre), shards)?
                    .with_journal_path(&migration_journal);
                let (stats, dur) = provspark::util::timer::time_it(|| {
                    let mut res = session.ingest(&batch);
                    // `--retries` here is a recovery budget: each attempt
                    // resumes the journaled plan from its cursor rather
                    // than starting the batch over.
                    for _ in 0..retries {
                        if res.is_ok() || !session.has_pending() {
                            break;
                        }
                        if let Err(e) = &res {
                            eprintln!("ingest interrupted: {e:#}; recovering");
                        }
                        res = session.recover();
                    }
                    res
                });
                let stats = stats?;
                let (merged_trace, merged_pre) = session.merged_state()?;
                store::save_trace_atomic(&staged_path(&finals[0]), &merged_trace)?;
                store::save_preprocessed_atomic(&staged_path(&finals[1]), &merged_pre)?;
                commit_files(&publish_journal, &finals)?;
                println!(
                    "ingested {} triples across {shards} shards in {} (index now {} \
                     triples, {} components, {} sets)",
                    human_count(batch_len as u64),
                    human_duration(dur),
                    human_count(merged_trace.len() as u64),
                    human_count(merged_pre.component_count as u64),
                    human_count(merged_pre.set_count as u64),
                );
                println!("  {}", stats.summary());
                for (i, d) in stats.per_shard.iter().enumerate() {
                    if let Some(d) = d {
                        println!("  shard {i}: {}", d.summary());
                    }
                }
                println!("→ {out_trace}, {out_pre}");
                return Ok(());
            }
            let (g, splits) = text_curation_workflow();
            let mut idx = IncrementalIndex::new(trace, pre, g, splits)?;
            let (delta, dur) = provspark::util::timer::time_it(|| idx.apply(&batch));
            let delta = delta?;
            // Two-phase publish: the defaults overwrite the inputs in
            // place, and trace + index are *two* files — staging both and
            // committing through a journal closes the crash window where
            // one is new and the other old (two bare renames cannot).
            store::save_trace_atomic(&staged_path(&finals[0]), idx.trace())?;
            store::save_preprocessed_atomic(&staged_path(&finals[1]), idx.pre())?;
            commit_files(&publish_journal, &finals)?;
            println!(
                "ingested {} triples in {} (epoch {}; index now {} triples, {} components, \
                 {} sets)",
                human_count(batch_len as u64),
                human_duration(dur),
                idx.epoch(),
                human_count(idx.trace().len() as u64),
                human_count(idx.pre().component_count as u64),
                human_count(idx.pre().set_count as u64),
            );
            println!("  {}", delta.stats.summary());
            println!("→ {out_trace}, {out_pre}");
            Ok(())
        }
        "query" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let pre_path = args.get_or("pre", "data/pre.bin");
            let ecfg = engine_config(args)?;
            let router: EngineRouter = args.get_or("engine", "auto").parse()?;
            let items = args.get_all("item");
            if items.is_empty() {
                bail!("--item required (raw id or e:serial; repeat for a batch)");
            }
            let deadline = args
                .get("deadline-ms")
                .map(|ms| ms.parse::<u64>().context("--deadline-ms"))
                .transpose()?
                .map(Duration::from_millis);
            let retries: u32 = args.get_parsed_or("retries", 0)?;
            let mut reqs = Vec::with_capacity(items.len());
            for item in items {
                let mut req = QueryRequest::new(parse_item(item)?);
                req.max_depth = args.get("max-depth").map(str::parse).transpose()?;
                req.max_triples = args.get("max-triples").map(str::parse).transpose()?;
                req.tau_override = args.get("tau-override").map(str::parse).transpose()?;
                req.deadline = deadline;
                req.retries = retries;
                reqs.push(req);
            }
            let shards: usize = args.get_parsed_or("shards", 1)?;
            let (responses, outcomes, shard_report, metrics, dur) = if shards > 1 {
                let pre = store::load_preprocessed(Path::new(&pre_path))?;
                let session =
                    ShardedSession::new(&ecfg, Arc::new(trace), Arc::new(pre), shards)?;
                let ((responses, report), dur) = provspark::util::timer::time_it(|| {
                    session.query_many_report_on(router, &reqs)
                });
                let outcomes = report.outcomes.clone();
                let metrics = session.context().metrics().snapshot();
                (responses, outcomes, Some(report), metrics, dur)
            } else {
                // Budgeted sessions open a segmented (v4/v5) store
                // zero-copy: engines demand-page triple partitions through
                // the byte-budgeted cache instead of loading the whole
                // index up front. Older (v1–v3) files have no per-partition
                // directory, so they fall back to the full load.
                let session = if ecfg.cluster.memory_budget > 0 {
                    match store::SegmentedPre::open(Path::new(&pre_path)) {
                        Ok(seg) => {
                            let sc = MiniSpark::new(ecfg.cluster.clone());
                            ProvSession::with_context_segmented(
                                &sc,
                                &ecfg,
                                Arc::new(trace),
                                Arc::new(seg),
                            )?
                        }
                        Err(_) => {
                            let pre = store::load_preprocessed(Path::new(&pre_path))?;
                            ProvSession::new(&ecfg, Arc::new(trace), Arc::new(pre))?
                        }
                    }
                } else {
                    let pre = store::load_preprocessed(Path::new(&pre_path))?;
                    ProvSession::new(&ecfg, Arc::new(trace), Arc::new(pre))?
                };
                // Supervised execution: per-item retry budget, failures
                // isolated (a failed item reports `failed`, the rest of the
                // batch still answers).
                let (pairs, dur) = provspark::util::timer::time_it(|| {
                    session.query_many_outcomes_on(router, &reqs)
                });
                let (responses, outcomes): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
                let metrics = session.context().metrics().snapshot();
                (responses, outcomes, None, metrics, dur)
            };
            for ((req, resp), outcome) in reqs.iter().zip(&responses).zip(&outcomes) {
                let lineage = &resp.lineage;
                println!(
                    "{} ({}): {} ancestors, {} triples, {} transformations in {}",
                    req.item,
                    AttrValueId(req.item),
                    lineage.ancestors.len(),
                    lineage.triples.len(),
                    lineage.transformation_count(),
                    human_duration(resp.stats.total_time()),
                );
                println!("  stats: {}", resp.stats.summary());
                let c = &resp.stats.completeness;
                if c.exhausted {
                    println!("  outcome: {outcome}");
                } else {
                    println!(
                        "  outcome: {outcome} — a depth-{} prefix of the full lineage \
                         ({} frontier node(s) unexplored at the cut)",
                        c.rounds_done, c.frontier_remaining,
                    );
                }
                if args.has_flag("verbose") {
                    for t in &lineage.triples {
                        println!("  {} -> {} via op{}", t.src, t.dst, t.op.0);
                    }
                }
            }
            if reqs.len() > 1 {
                println!(
                    "batch of {} answered in {} (router: {router})",
                    reqs.len(),
                    human_duration(dur),
                );
            }
            if let Some(report) = shard_report {
                print!("{}", report.summary());
            }
            if ecfg.cluster.memory_budget > 0 {
                // Out-of-core sessions: show how the byte-budgeted cache
                // behaved (hits/misses/evictions and spill/page-in volume
                // are part of the engine-wide metrics summary), and break
                // the page-in volume into on-disk vs decoded bytes — the
                // gap is what the v5 columnar encoding saved on the wire.
                println!(
                    "memory budget {}: {}",
                    provspark::util::fmt::human_bytes(ecfg.cluster.memory_budget),
                    metrics.summary(),
                );
                println!(
                    "  io: {} read from disk, {} decoded in memory ({} saved by the \
                     columnar encoding); prefetch issued {}, hits {}",
                    provspark::util::fmt::human_bytes(metrics.bytes_paged_in),
                    provspark::util::fmt::human_bytes(metrics.bytes_decoded),
                    provspark::util::fmt::human_bytes(metrics.bytes_compressed),
                    metrics.prefetch_issued,
                    metrics.prefetch_hits,
                );
            }
            Ok(())
        }
        "serve" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let pre = store::load_preprocessed(Path::new(&args.get_or("pre", "data/pre.bin")))?;
            let ecfg = engine_config(args)?;
            let router: EngineRouter = args.get_or("engine", "auto").parse()?;
            let shards: usize = args.get_parsed_or("shards", 1)?;
            let tenants: usize = args.get_parsed_or("tenants", 2)?;
            let requests: usize = args.get_parsed_or("requests", 32)?;
            let deadline = args
                .get("deadline-ms")
                .map(|ms| ms.parse::<u64>().context("--deadline-ms"))
                .transpose()?
                .map(Duration::from_millis);
            let mut scfg = ServeConfig::default();
            scfg.window = Duration::from_millis(args.get_parsed_or("window-ms", 2u64)?);
            scfg.window_max = args.get_parsed_or("window-max", scfg.window_max)?;
            scfg.queue_capacity = args.get_parsed_or("queue-capacity", scfg.queue_capacity)?;
            scfg.quota_qps = args.get_parsed_or("quota-qps", scfg.quota_qps)?;
            scfg.quota_burst = args.get_parsed_or("quota-burst", scfg.quota_burst)?;
            // Tenants round-robin over a sampled item set, offset per
            // tenant, so windows genuinely coalesce and later laps hit the
            // cache.
            let items: Vec<u64> = {
                let n = (requests * 2).clamp(8, 256);
                let step = (trace.len() / n).max(1);
                trace.triples.iter().step_by(step).map(|t| t.dst.raw()).take(n).collect()
            };
            let session = Arc::new(
                ShardedSession::new(&ecfg, Arc::new(trace), Arc::new(pre), shards)?
                    .with_router(router),
            );
            let front = Arc::new(ServeFront::new(Arc::clone(&session), scfg));
            let t0 = std::time::Instant::now();
            let mut workers = Vec::new();
            for tn in 0..tenants {
                let front = Arc::clone(&front);
                let items = items.clone();
                // The last tenant is the "interactive" one: its requests
                // carry the deadline and stream partial-then-full answers.
                let tenant_deadline = if tn + 1 == tenants { deadline } else { None };
                workers.push(std::thread::spawn(move || {
                    let name = format!("tenant{tn}");
                    let (mut full, mut partial, mut failed) = (0usize, 0usize, 0usize);
                    let (mut cached, mut completed, mut rejected) = (0usize, 0usize, 0usize);
                    for i in 0..requests {
                        let mut req = QueryRequest::new(items[(i + tn * 3) % items.len()]);
                        req.deadline = tenant_deadline;
                        match front.submit(&name, req) {
                            Ok(handle) => {
                                let Some(first) =
                                    handle.recv_timeout(Duration::from_secs(60))
                                else {
                                    failed += 1;
                                    continue;
                                };
                                if first.from_cache {
                                    cached += 1;
                                }
                                match first.outcome {
                                    QueryOutcome::Full => full += 1,
                                    QueryOutcome::Failed => failed += 1,
                                    QueryOutcome::Partial => {
                                        partial += 1;
                                        // The background-completed answer
                                        // streams in as a second response.
                                        if handle
                                            .recv_timeout(Duration::from_secs(60))
                                            .is_some()
                                        {
                                            completed += 1;
                                        }
                                    }
                                }
                            }
                            Err(_) => rejected += 1,
                        }
                    }
                    (name, full, partial, failed, cached, completed, rejected)
                }));
            }
            // Concurrent ingest load: bridge sampled items pairwise so
            // merges really dirty components and sweep cache entries.
            let batches: usize = args.get_parsed_or("ingest-batches", 0)?;
            for b in 0..batches {
                let a = items[b % items.len()];
                let c = items[(b * 7 + 3) % items.len()];
                let batch = TripleBatch::new(vec![ProvTriple::new(
                    AttrValueId(a),
                    AttrValueId(c),
                    OpId(0),
                )]);
                let stats = front.ingest(&batch)?;
                println!("ingest batch {b}: {}", stats.summary());
            }
            for w in workers {
                let (name, full, partial, failed, cached, completed, rejected) =
                    w.join().expect("tenant thread panicked");
                println!(
                    "{name}: {full} full, {partial} partial (+{completed} completed), \
                     {failed} failed, {cached} from cache, {rejected} rejected",
                );
            }
            front.wait_for_completions();
            let dur = t0.elapsed();
            let report = front.report();
            println!("{}", report.summary());
            let answered = report.admitted as f64;
            println!(
                "mixed-tenant workload: {tenants} tenants x {requests} requests over \
                 {shards} shard(s) in {} ({:.0} answers/s)",
                human_duration(dur),
                answered / dur.as_secs_f64().max(1e-9),
            );
            Ok(())
        }
        "classes" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let pre = store::load_preprocessed(Path::new(&args.get_or("pre", "data/pre.bin")))?;
            let divisor: usize = args.get_parsed_or("scale-divisor", 10)?;
            let class: QueryClass = args.get_or("class", "lc-sl").parse()?;
            let count: usize = args.get_parsed_or("count", 10)?;
            let seed: u64 = args.get_parsed_or("seed", 42)?;
            let sel = select_queries(&trace, &pre, class, count, divisor, seed)?;
            println!(
                "{} items in component {} with ancestors in [{}, {}]:",
                sel.class, sel.component, sel.band.0, sel.band.1
            );
            for q in &sel.items {
                println!("  {q} ({})", AttrValueId(*q));
            }
            Ok(())
        }
        "table" => {
            let which: u32 = args.get_parsed_or("which", 9)?;
            let divisor: usize = args.get_parsed_or("divisor", 10)?;
            let mut xcfg = ExperimentConfig::for_divisor(divisor);
            xcfg.engine = engine_config(args)?;
            if let Some(reps) = args.get("replications") {
                xcfg.replications = reps
                    .split(',')
                    .map(|r| r.parse::<usize>().context("replication"))
                    .collect::<Result<_>>()?;
            }
            xcfg.queries_per_class = args.get_parsed_or("count", 10)?;
            match which {
                9 => {
                    let (_, pre) = xcfg.build_scale(1);
                    table9(&pre).print();
                    component_census(&pre).print();
                }
                10 => query_table(QueryClass::ScSl, &xcfg)?.0.print(),
                11 => query_table(QueryClass::LcSl, &xcfg)?.0.print(),
                12 => query_table(QueryClass::LcLl, &xcfg)?.0.print(),
                other => bail!("unknown table {other} (9|10|11|12)"),
            }
            Ok(())
        }
        "drilldown" => {
            let trace = store::load_trace(Path::new(&args.get_or("trace", "data/trace.bin")))?;
            let pre = store::load_preprocessed(Path::new(&args.get_or("pre", "data/pre.bin")))?;
            let ecfg = engine_config(args)?;
            let q = parse_item(args.get("item").ok_or_else(|| anyhow!("--item required"))?)?;
            let session = ProvSession::new(&ecfg, Arc::new(trace), Arc::new(pre))?;
            print!("{}", drilldown_report(&session, q));
            Ok(())
        }
        "workflow" => {
            let (g, splits) = text_curation_workflow();
            if args.has_flag("dot") {
                print!("{}", g.to_dot(|e| splits.split_of(e).map(|s| s.to_string())));
            } else {
                println!("{} entities, {} derivations", g.entity_count(), g.edges().len());
                for sp in splits.top_level() {
                    let names: Vec<&str> =
                        sp.entities().iter().map(|&e| g.name_of(e)).collect();
                    println!("  {}: {}", sp.name(), names.join(", "));
                }
            }
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}
