//! Benchmark harness (the offline build has no `criterion`).
//!
//! Used by every `benches/*.rs` target (`harness = false`). Provides
//! warmup + timed iterations, robust summary statistics, and markdown
//! table rendering so each bench binary prints exactly the rows the
//! paper's tables report.

use crate::util::fmt::{human_duration, pad};
use std::time::{Duration, Instant};

/// Summary statistics over a set of timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty(), "no samples");
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Stats {
            iters: n,
            mean: sum / n as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on measurement wall-time; stops early once exceeded
    /// (at least one iteration always runs).
    pub max_time: Duration,
}

impl Default for BenchCfg {
    fn default() -> Self {
        Self { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(60) }
    }
}

/// Run `f` under `cfg`, returning stats. `f` receives the iteration index.
pub fn run_bench(cfg: &BenchCfg, mut f: impl FnMut(usize)) -> Stats {
    for i in 0..cfg.warmup_iters {
        f(i);
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let started = Instant::now();
    for i in 0..cfg.iters {
        let t0 = Instant::now();
        f(i);
        samples.push(t0.elapsed());
        if started.elapsed() > cfg.max_time && !samples.is_empty() {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// A plain-text/markdown table builder for bench reports.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a markdown table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let render_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {} |", pad(&cells[i], widths[i])));
            }
            line.push('\n');
            line
        };
        out.push_str(&render_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&render_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a `Stats` mean as the canonical cell used in tables.
pub fn cell(stats: &Stats) -> String {
    human_duration(stats.mean)
}

/// Parse common bench CLI knobs (`--iters`, `--warmup`) from an `Args`.
pub fn cfg_from_args(args: &crate::cli::Args) -> BenchCfg {
    let mut cfg = BenchCfg::default();
    if let Ok(i) = args.get_parsed_or("iters", cfg.iters) {
        cfg.iters = i.max(1);
    }
    if let Ok(w) = args.get_parsed_or("warmup", cfg.warmup_iters) {
        cfg.warmup_iters = w;
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = Stats::from_samples(samples);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(100));
        assert!(s.p50 >= Duration::from_millis(50) && s.p50 <= Duration::from_millis(52));
        assert!(s.p95 >= Duration::from_millis(95));
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let cfg = BenchCfg { warmup_iters: 2, iters: 3, max_time: Duration::from_secs(10) };
        let s = run_bench(&cfg, |_| count += 1);
        assert_eq!(count, 5);
        assert_eq!(s.iters, 3);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| a "));
        assert!(r.contains("| 1 "));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
