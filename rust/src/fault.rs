//! Deterministic fault injection: seed-driven panic / delay / io-error
//! probes keyed by site × probe index.
//!
//! A [`FaultPlan`] is parsed from a compact spec string (the `--fault-plan`
//! CLI flag or the `cluster.fault_plan` config key):
//!
//! ```text
//! panic:shuffle:0.05,seed=6      # 5% of shuffle tasks panic
//! delay:task:0.2,io:store:@1     # 20% of tasks stall; 2nd store IO errors
//! ```
//!
//! Each clause is `kind:site:trigger` with kind ∈ {`panic`, `delay`, `io`},
//! site ∈ {`task`, `shuffle`, `store`, `journal`, `segment`}, and a
//! trigger that is
//! either a firing probability in `[0, 1]` or `@N` (fire exactly on the
//! N-th probe of that site, 0-based). A trailing `seed=N` fixes the
//! probability draws.
//!
//! A [`FaultInjector`] owns one monotone counter per site; every probe
//! consumes one index, and whether index `i` of site `s` fires is a pure
//! function of `(seed, s, i)` — a run's fault *pattern* is reproducible
//! from the plan string alone no matter how work interleaves across worker
//! threads (which thread draws a firing index may vary; the set of firing
//! indices does not). Two consequences the fault-tolerance layer leans on:
//! retried tasks draw *fresh* indices, so a probability fault almost
//! always clears on retry (the transient-failure model Spark's task
//! supervision assumes), and an exact `@N` clause can never re-fire during
//! journal recovery, which makes crash-replay tests deterministic without
//! ever clearing the plan.

use anyhow::{bail, ensure, Result};
use std::cell::RefCell;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What an armed probe does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic in the probing thread (a crashed task / process step).
    Panic,
    /// Stall the probing thread briefly (a straggler).
    Delay,
    /// Fail with an error. At IO probes this is a returned `Err`; at task
    /// probes an IO error still surfaces as a task failure (panic), since
    /// task closures have no error channel.
    Io,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FaultKind::Panic => "panic",
            FaultKind::Delay => "delay",
            FaultKind::Io => "io",
        })
    }
}

/// Where a probe is planted. Each site has its own monotone probe counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Every task dispatched through the worker pool.
    Task,
    /// Map-side tasks of the `Dataset` shuffle paths.
    Shuffle,
    /// `store` load/save entry points.
    StoreIo,
    /// Each step of a journaled shard-migration apply.
    Journal,
    /// Segment-store IO: spill writes, segment-file opens and the
    /// demand-paging reads of the partition cache.
    SegmentIo,
}

/// All sites, in counter-index order.
const SITES: [FaultSite; 5] = [
    FaultSite::Task,
    FaultSite::Shuffle,
    FaultSite::StoreIo,
    FaultSite::Journal,
    FaultSite::SegmentIo,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::Task => 0,
            FaultSite::Shuffle => 1,
            FaultSite::StoreIo => 2,
            FaultSite::Journal => 3,
            FaultSite::SegmentIo => 4,
        }
    }
}

impl fmt::Display for FaultSite {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FaultSite::Task => "task",
            FaultSite::Shuffle => "shuffle",
            FaultSite::StoreIo => "store",
            FaultSite::Journal => "journal",
            FaultSite::SegmentIo => "segment",
        })
    }
}

/// When a probe fires: on a deterministic pseudo-random draw, or exactly
/// on one probe index.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    Prob(f64),
    At(u64),
}

/// One `kind:site:trigger` clause of a plan.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Probe {
    kind: FaultKind,
    site: FaultSite,
    trigger: Trigger,
}

impl fmt::Display for Probe {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.trigger {
            Trigger::Prob(p) => write!(f, "{}:{}:{}", self.kind, self.site, p),
            Trigger::At(n) => write!(f, "{}:{}:@{}", self.kind, self.site, n),
        }
    }
}

/// A deterministic fault schedule: a set of probes plus the seed driving
/// their probability draws. Parsed from / printed as the spec grammar in
/// the module docs ([`FromStr`] and [`Display`](fmt::Display) round-trip).
///
/// ```
/// use provspark::fault::FaultPlan;
/// let plan: FaultPlan = "panic:shuffle:0.05,seed=6".parse().unwrap();
/// assert_eq!(plan.to_string().parse::<FaultPlan>().unwrap(), plan);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    probes: Vec<Probe>,
    seed: u64,
}

impl FaultPlan {
    /// The seed driving probability draws.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True if no clause targets `site` (its probes can short-circuit).
    pub fn is_silent_at(&self, site: FaultSite) -> bool {
        self.probes.iter().all(|p| p.site != site)
    }
}

impl FromStr for FaultPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut probes = Vec::new();
        let mut seed = 0u64;
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(v) = clause.strip_prefix("seed=") {
                seed = v.parse().map_err(|e| {
                    anyhow::anyhow!("fault plan: bad seed {v:?} in {clause:?}: {e}")
                })?;
                continue;
            }
            let mut parts = clause.split(':');
            let (kind, site, trig) = match (parts.next(), parts.next(), parts.next()) {
                (Some(k), Some(s), Some(t)) if parts.next().is_none() => (k, s, t),
                _ => bail!("fault plan: clause {clause:?} is not kind:site:trigger"),
            };
            let kind = match kind {
                "panic" => FaultKind::Panic,
                "delay" => FaultKind::Delay,
                "io" => FaultKind::Io,
                other => bail!("fault plan: unknown kind {other:?} (panic|delay|io)"),
            };
            let site = match site {
                "task" => FaultSite::Task,
                "shuffle" => FaultSite::Shuffle,
                "store" => FaultSite::StoreIo,
                "journal" => FaultSite::Journal,
                "segment" => FaultSite::SegmentIo,
                other => {
                    bail!(
                        "fault plan: unknown site {other:?} \
                         (task|shuffle|store|journal|segment)"
                    )
                }
            };
            let trigger = if let Some(n) = trig.strip_prefix('@') {
                Trigger::At(n.parse().map_err(|e| {
                    anyhow::anyhow!("fault plan: bad probe index in {clause:?}: {e}")
                })?)
            } else {
                let p: f64 = trig.parse().map_err(|e| {
                    anyhow::anyhow!("fault plan: bad probability in {clause:?}: {e}")
                })?;
                ensure!(
                    (0.0..=1.0).contains(&p),
                    "fault plan: probability {p} in {clause:?} outside [0, 1]"
                );
                Trigger::Prob(p)
            };
            probes.push(Probe { kind, site, trigger });
        }
        ensure!(!probes.is_empty(), "fault plan: no probe clauses in {s:?}");
        Ok(Self { probes, seed })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for p in &self.probes {
            write!(f, "{p},")?;
        }
        write!(f, "seed={}", self.seed)
    }
}

/// The runtime half of a [`FaultPlan`]: per-site probe counters plus a
/// fired-fault tally. Shared (`Arc`) between the driver, the worker pool
/// and — via [`install_io_faults`] — the store's thread-local slot.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    counters: [AtomicU64; 5],
    fired: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, counters: Default::default(), fired: AtomicU64::new(0) }
    }

    /// Draw the next probe index for `site` and decide whether it fires.
    /// Deterministic in `(seed, site, index)`; sites with no clause don't
    /// consume indices (so unrelated sites never perturb each other).
    fn draw(&self, site: FaultSite) -> Option<(FaultKind, u64)> {
        if self.plan.is_silent_at(site) {
            return None;
        }
        let idx = self.counters[site.index()].fetch_add(1, Ordering::Relaxed);
        for p in self.plan.probes.iter().filter(|p| p.site == site) {
            let hit = match p.trigger {
                Trigger::At(n) => idx == n,
                Trigger::Prob(prob) => unit_draw(self.plan.seed, site, idx) < prob,
            };
            if hit {
                self.fired.fetch_add(1, Ordering::Relaxed);
                return Some((p.kind, idx));
            }
        }
        None
    }

    /// Probe from inside a task or process step: a firing `panic`/`io`
    /// clause panics (tasks have no error channel; the supervisor converts
    /// the panic to a typed error), a `delay` clause stalls ~2ms.
    pub fn fire_task(&self, site: FaultSite) {
        if let Some((kind, idx)) = self.draw(site) {
            match kind {
                FaultKind::Delay => {
                    std::thread::sleep(std::time::Duration::from_millis(2))
                }
                FaultKind::Panic | FaultKind::Io => {
                    panic!("injected {kind} fault at {site} probe #{idx}")
                }
            }
        }
    }

    /// Probe from an IO path: a firing `io`/`panic` clause returns a named
    /// error (IO code must *never* panic — that is what this layer tests),
    /// a `delay` clause stalls ~2ms.
    pub fn fire_io(&self, site: FaultSite) -> Result<()> {
        if let Some((kind, idx)) = self.draw(site) {
            match kind {
                FaultKind::Delay => {
                    std::thread::sleep(std::time::Duration::from_millis(2))
                }
                FaultKind::Panic | FaultKind::Io => {
                    bail!("injected {kind} fault at {site} probe #{idx}")
                }
            }
        }
        Ok(())
    }

    /// How many probes have fired so far.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// The plan this injector executes.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

/// Map `(seed, site, index)` to a uniform draw in `[0, 1)` via two rounds
/// of splitmix64 (the 53 high bits become the mantissa).
fn unit_draw(seed: u64, site: FaultSite, idx: u64) -> f64 {
    let mut x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((site.index() as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(idx);
    for _ in 0..2 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
    }
    (x >> 11) as f64 / (1u64 << 53) as f64
}

thread_local! {
    /// The store's fault slot. Store IO runs on whatever thread calls
    /// `load_*`/`save_*` (the driver, in the CLI), so a thread-local keeps
    /// concurrently running tests from injecting into each other.
    static IO_FAULTS: RefCell<Option<Arc<FaultInjector>>> = const { RefCell::new(None) };
}

/// Install (or clear, with `None`) the fault injector consulted by
/// [`io_probe`] on this thread.
pub fn install_io_faults(injector: Option<Arc<FaultInjector>>) {
    IO_FAULTS.with(|slot| *slot.borrow_mut() = injector);
}

/// Probe the thread's installed IO injector, if any. Store load/save entry
/// points call this; with nothing installed it is a no-op.
pub fn io_probe(site: FaultSite) -> Result<()> {
    IO_FAULTS.with(|slot| match slot.borrow().as_ref() {
        Some(inj) => inj.fire_io(site),
        None => Ok(()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_and_round_trips() {
        for spec in
            ["panic:shuffle:0.05,seed=6", "delay:task:0.2,io:store:@1,seed=0", "panic:journal:@3"]
        {
            let plan: FaultPlan = spec.parse().unwrap();
            let back: FaultPlan = plan.to_string().parse().unwrap();
            assert_eq!(back, plan, "{spec}");
        }
    }

    #[test]
    fn plan_rejects_malformed_specs() {
        for bad in [
            "",
            "seed=4",
            "panic:shuffle",
            "panic:nowhere:0.1",
            "explode:task:0.1",
            "panic:task:1.5",
            "panic:task:@x",
            "seed=abc,panic:task:0.1",
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn exact_trigger_fires_once_at_its_index() {
        let inj = FaultInjector::new("io:store:@2".parse().unwrap());
        assert!(inj.fire_io(FaultSite::StoreIo).is_ok());
        assert!(inj.fire_io(FaultSite::StoreIo).is_ok());
        let err = inj.fire_io(FaultSite::StoreIo).unwrap_err();
        assert!(err.to_string().contains("store probe #2"), "{err}");
        for _ in 0..8 {
            assert!(inj.fire_io(FaultSite::StoreIo).is_ok());
        }
        assert_eq!(inj.fired(), 1);
    }

    #[test]
    fn probability_draws_are_deterministic_and_site_local() {
        let mk = || FaultInjector::new("io:task:0.3,seed=42".parse().unwrap());
        let (a, b) = (mk(), mk());
        let pattern = |inj: &FaultInjector| -> Vec<bool> {
            (0..200).map(|_| inj.draw(FaultSite::Task).is_some()).collect()
        };
        let pa = pattern(&a);
        assert_eq!(pa, pattern(&b), "same seed must fire the same indices");
        let hits = pa.iter().filter(|&&h| h).count();
        assert!((20..=100).contains(&hits), "0.3 over 200 draws fired {hits} times");
        // Sites without a clause never fire and never consume indices.
        for site in SITES {
            if site != FaultSite::Task {
                assert!(a.draw(site).is_none());
            }
        }
        assert_eq!(a.counters[FaultSite::Shuffle.index()].load(Ordering::Relaxed), 0);
    }

    #[test]
    fn seed_changes_the_pattern() {
        let a = FaultInjector::new("panic:shuffle:0.2,seed=1".parse().unwrap());
        let b = FaultInjector::new("panic:shuffle:0.2,seed=2".parse().unwrap());
        let pat = |inj: &FaultInjector| -> Vec<bool> {
            (0..256).map(|_| inj.draw(FaultSite::Shuffle).is_some()).collect()
        };
        assert_ne!(pat(&a), pat(&b));
    }

    #[test]
    fn io_probe_without_installation_is_a_noop() {
        install_io_faults(None);
        assert!(io_probe(FaultSite::StoreIo).is_ok());
        install_io_faults(Some(Arc::new(FaultInjector::new(
            "io:store:@0".parse().unwrap(),
        ))));
        assert!(io_probe(FaultSite::StoreIo).is_err());
        assert!(io_probe(FaultSite::StoreIo).is_ok());
        install_io_faults(None);
    }
}
