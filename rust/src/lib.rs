//! # provspark
//!
//! A reproduction of *"Efficiently Processing Workflow Provenance Queries on
//! SPARK"* (Rajmohan et al., 2018) as a self-contained Rust + JAX/Pallas
//! (AOT via XLA/PJRT) stack.
//!
//! The crate contains:
//!
//! * [`minispark`] — an embedded, partitioned, Spark-shaped dataflow engine
//!   (hash-partitioned datasets, `filter`/`lookup`/`collect`, a job
//!   scheduler with configurable job-launch overhead, shuffle, caching and
//!   metrics). This is the substrate the paper's algorithms run on.
//! * [`provenance`] — the paper's contribution: the provenance data model,
//!   weakly-connected-component computation, Algorithm 3 component
//!   partitioning, set dependencies, the three query engines
//!   (`RQ`, `CCProv`, `CSProv`), and — beyond the paper — incremental
//!   index maintenance ([`provenance::incremental`]) so deltas of new
//!   triples are absorbed without re-preprocessing.
//! * [`workflow`] — the workflow dependency graph, a synthetic text-curation
//!   workload shaped like the paper's Figure 1, and the provenance trace
//!   generator + replication-based scaling.
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled HLO artifacts
//!   (produced by `python/compile/aot.py`) and exposes the XLA-backed
//!   label-propagation / reachability fixpoints.
//! * [`harness`] — the [`harness::ProvSession`] query service (routing,
//!   batched execution, live ingestion with epoch swaps) and experiment
//!   drivers that regenerate every table in the paper's evaluation section.
//! * [`serve`] — the multi-tenant serving front over
//!   [`harness::ShardedSession`]: per-tenant admission control, a
//!   micro-batching scatter window, an epoch-keyed result cache with
//!   dirty-component invalidation, and streaming deadline-bounded partial
//!   answers.
//!
//! Start with the repository-level `README.md` (quickstart, engine menu)
//! and `ARCHITECTURE.md` (paper-concept → module map, data-flow diagram).
//!
//! Support substrates built in-tree (the build environment is offline):
//! [`exec`] (thread pool), [`cli`] (argument parser), [`benchkit`]
//! (benchmark harness), [`proptest_lite`] (property testing), [`config`],
//! [`fault`] (deterministic fault injection for the robustness tests).

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod exec;
pub mod fault;
pub mod harness;
pub mod minispark;
pub mod proptest_lite;
pub mod provenance;
pub mod runtime;
pub mod serve;
pub mod storage;
pub mod util;
pub mod workflow;
