//! Configuration system.
//!
//! One [`EngineConfig`] drives the whole stack: the minispark cluster shape
//! (executor/partition counts, simulated job-launch overhead), the paper's
//! thresholds (τ for driver-collect, θ for component partitioning), and the
//! compute backends (native Rust vs. AOT-compiled XLA artifacts).
//!
//! Configs load from a `key = value` file (a TOML subset — sections become
//! key prefixes) and can be overridden by CLI options; every experiment in
//! EXPERIMENTS.md records the exact config it ran with.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which implementation executes a dense compute phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust implementation.
    Native,
    /// AOT-compiled HLO artifact executed via PJRT (see `runtime`).
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => bail!("unknown backend {other:?} (expected native|xla)"),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Backend::Native => "native",
            Backend::Xla => "xla",
        })
    }
}

/// Cluster-shape settings for the embedded minispark engine.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Worker threads in the executor pool (the paper: 8 nodes × 12 cores;
    /// here logical workers on however many cores the box has).
    pub executors: usize,
    /// Default number of partitions for newly created datasets.
    pub default_partitions: usize,
    /// Simulated per-job scheduling overhead, in microseconds. Models
    /// Spark's job/stage launch cost — the effect behind the paper's τ
    /// driver-collect optimization. 0 disables simulation.
    ///
    /// Default 20 ms: Spark 1.6's per-job latency on the paper's cluster is
    /// ~200 ms; our default trace is 1/10 of the paper's, so the overhead
    /// scales by the same factor to preserve the compute-vs-overhead ratio
    /// the evaluation's shape depends on (see DESIGN.md §2).
    pub job_overhead_us: u64,
    /// Skip shuffles that are provably no-ops: re-partitioning a dataset
    /// that is already hash-partitioned on the same key tag with the same
    /// partition count returns it unchanged (Spark's narrow-dependency
    /// optimization). Disable to force every shuffle — property tests use
    /// this to check elision never changes results.
    pub shuffle_elision: bool,
    /// Deterministic fault-injection schedule (see [`crate::fault`]).
    /// `None` disables injection — the probes short-circuit on one branch
    /// check, which is what keeps the happy path free.
    pub fault_plan: Option<crate::fault::FaultPlan>,
    /// Extra attempts a supervised task gets after its first failure
    /// (Spark's `spark.task.maxFailures` minus one).
    pub task_retries: u32,
    /// Base backoff between task retry attempts, in microseconds; doubles
    /// per failure, capped at 32× (see [`crate::exec::RetryPolicy`]).
    pub retry_backoff_us: u64,
    /// Byte budget for resident (decoded) dataset partitions. `0` means
    /// unbounded: datasets stay fully in memory and nothing spills. Any
    /// other value makes engine datasets spill to segment files and page
    /// partitions through the byte-budgeted cache
    /// (see [`crate::storage`]). Accepts `k`/`m`/`g` suffixes on the CLI
    /// and in config files.
    pub memory_budget: u64,
    /// Maximum partitions the frontier-driven readahead warms per BFS
    /// round (see [`crate::storage::prefetch`]). `0` disables prefetch.
    /// Prefetch is also disabled process-wide by `PROVSPARK_PREFETCH=off`
    /// and automatically whenever a fault plan is armed.
    pub prefetch_depth: usize,
    /// Adapt the readahead depth at runtime from the observed
    /// `prefetch_hits / prefetch_issued` ratio: halve on a low hit rate,
    /// grow back toward `prefetch_depth` (the cap) on a high one. On by
    /// default; giving a depth explicitly (`--prefetch-depth` /
    /// `cluster.prefetch_depth`) pins that fixed depth instead unless
    /// adaptation is also requested explicitly.
    pub prefetch_adaptive: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            executors: 4,
            default_partitions: 64,
            job_overhead_us: 20_000,
            shuffle_elision: true,
            fault_plan: None,
            task_retries: 2,
            retry_backoff_us: 200,
            memory_budget: 0,
            prefetch_depth: 16,
            prefetch_adaptive: true,
        }
    }
}

/// Settings for the provenance framework itself.
#[derive(Debug, Clone)]
pub struct ProvConfig {
    /// τ — if a component / set-lineage has fewer triples than this, collect
    /// to the driver and recurse locally (Algorithms 1–2).
    pub tau: usize,
    /// θ — Algorithm 3 recurses on any split-component with ≥ θ nodes.
    pub theta: usize,
    /// Backend for WCC preprocessing.
    pub wcc_backend: Backend,
    /// Backend for the driver-side ancestor closure.
    pub closure_backend: Backend,
    /// Directory holding AOT artifacts (`*.hlo.txt`).
    pub artifact_dir: String,
}

impl Default for ProvConfig {
    fn default() -> Self {
        Self {
            tau: 100_000,
            theta: 25_000,
            wcc_backend: Backend::Native,
            closure_backend: Backend::Native,
            artifact_dir: "artifacts".to_string(),
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    pub cluster: ClusterConfig,
    pub prov: ProvConfig,
}

impl EngineConfig {
    /// Load from a config file if given, then apply CLI overrides.
    pub fn from_sources(path: Option<&str>, args: &crate::cli::Args) -> Result<Self> {
        let mut cfg = EngineConfig::default();
        if let Some(p) = path {
            let kv = parse_kv_file(Path::new(p))
                .with_context(|| format!("loading config {p}"))?;
            cfg.apply_kv(&kv)?;
        }
        cfg.apply_args(args)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `section.key → value` pairs.
    pub fn apply_kv(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "cluster.executors" => self.cluster.executors = v.parse()?,
                "cluster.default_partitions" => self.cluster.default_partitions = v.parse()?,
                "cluster.job_overhead_us" => self.cluster.job_overhead_us = v.parse()?,
                "cluster.shuffle_elision" => self.cluster.shuffle_elision = v.parse()?,
                "cluster.fault_plan" => self.cluster.fault_plan = Some(v.parse()?),
                "cluster.task_retries" => self.cluster.task_retries = v.parse()?,
                "cluster.retry_backoff_us" => self.cluster.retry_backoff_us = v.parse()?,
                "cluster.memory_budget" => self.cluster.memory_budget = parse_bytes(v)?,
                "cluster.prefetch_depth" => {
                    self.cluster.prefetch_depth = v.parse()?;
                    // An explicit depth pins fixed-depth behavior — unless
                    // the same config also asks for adaptation explicitly.
                    if !kv.contains_key("cluster.prefetch_adaptive") {
                        self.cluster.prefetch_adaptive = false;
                    }
                }
                "cluster.prefetch_adaptive" => self.cluster.prefetch_adaptive = v.parse()?,
                "prov.tau" => self.prov.tau = v.parse()?,
                "prov.theta" => self.prov.theta = v.parse()?,
                "prov.wcc_backend" => self.prov.wcc_backend = v.parse()?,
                "prov.closure_backend" => self.prov.closure_backend = v.parse()?,
                "prov.artifact_dir" => self.prov.artifact_dir = v.clone(),
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// CLI overrides (flat names).
    pub fn apply_args(&mut self, args: &crate::cli::Args) -> Result<()> {
        self.cluster.executors = args.get_parsed_or("executors", self.cluster.executors)?;
        self.cluster.default_partitions =
            args.get_parsed_or("partitions", self.cluster.default_partitions)?;
        self.cluster.job_overhead_us =
            args.get_parsed_or("job-overhead-us", self.cluster.job_overhead_us)?;
        self.cluster.shuffle_elision =
            args.get_parsed_or("shuffle-elision", self.cluster.shuffle_elision)?;
        if let Some(spec) = args.get("fault-plan") {
            self.cluster.fault_plan = Some(spec.parse()?);
        }
        self.cluster.task_retries =
            args.get_parsed_or("task-retries", self.cluster.task_retries)?;
        self.cluster.retry_backoff_us =
            args.get_parsed_or("retry-backoff-us", self.cluster.retry_backoff_us)?;
        if let Some(spec) = args.get("memory-budget") {
            self.cluster.memory_budget = parse_bytes(spec)?;
        }
        if args.get("prefetch-depth").is_some() {
            self.cluster.prefetch_depth =
                args.get_parsed_or("prefetch-depth", self.cluster.prefetch_depth)?;
            // An explicit depth on the CLI pins fixed-depth behavior
            // unless adaptation is also requested explicitly.
            self.cluster.prefetch_adaptive = args.get_parsed_or("prefetch-adaptive", false)?;
        } else {
            self.cluster.prefetch_adaptive =
                args.get_parsed_or("prefetch-adaptive", self.cluster.prefetch_adaptive)?;
        }
        self.prov.tau = args.get_parsed_or("tau", self.prov.tau)?;
        self.prov.theta = args.get_parsed_or("theta", self.prov.theta)?;
        self.prov.wcc_backend = args.get_parsed_or("wcc-backend", self.prov.wcc_backend)?;
        self.prov.closure_backend =
            args.get_parsed_or("closure-backend", self.prov.closure_backend)?;
        if let Some(d) = args.get("artifact-dir") {
            self.prov.artifact_dir = d.to_string();
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.cluster.executors == 0 {
            bail!("cluster.executors must be >= 1");
        }
        if self.cluster.default_partitions == 0 {
            bail!("cluster.default_partitions must be >= 1");
        }
        if self.prov.theta < 2 {
            bail!("prov.theta must be >= 2 (cannot split below pairs)");
        }
        Ok(())
    }
}

/// Parse a byte count with an optional `k`/`m`/`g` (KiB/MiB/GiB) suffix,
/// case-insensitive: `"65536"`, `"64k"`, `"4m"`, `"2G"`.
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim();
    let (digits, mult) = match t.chars().last() {
        Some('k') | Some('K') => (&t[..t.len() - 1], 1u64 << 10),
        Some('m') | Some('M') => (&t[..t.len() - 1], 1u64 << 20),
        Some('g') | Some('G') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .with_context(|| format!("byte count {s:?} (expected digits with optional k/m/g)"))?;
    n.checked_mul(mult)
        .with_context(|| format!("byte count {s:?} overflows u64"))
}

/// Parse a TOML-subset file: `[section]` headers plus `key = value` lines;
/// `#` comments; quoted or bare values. Returns `section.key → value`.
pub fn parse_kv_file(path: &Path) -> Result<BTreeMap<String, String>> {
    let text = std::fs::read_to_string(path)?;
    parse_kv_str(&text)
}

/// See [`parse_kv_file`].
pub fn parse_kv_str(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        if out.insert(key.clone(), val).is_some() {
            bail!("line {}: duplicate key {key:?}", lineno + 1);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_comments() {
        let kv = parse_kv_str(
            "# comment\n[cluster]\nexecutors = 8 # inline\n\n[prov]\ntau = \"5000\"\n",
        )
        .unwrap();
        assert_eq!(kv.get("cluster.executors").unwrap(), "8");
        assert_eq!(kv.get("prov.tau").unwrap(), "5000");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse_kv_str("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn apply_kv_roundtrip() {
        let mut cfg = EngineConfig::default();
        let kv = parse_kv_str("[prov]\ntheta = 123\nwcc_backend = xla\n").unwrap();
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.prov.theta, 123);
        assert_eq!(cfg.prov.wcc_backend, Backend::Xla);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = EngineConfig::default();
        let kv = parse_kv_str("bogus = 1\n").unwrap();
        assert!(cfg.apply_kv(&kv).is_err());
    }

    #[test]
    fn validation_catches_zero_executors() {
        let mut cfg = EngineConfig::default();
        cfg.cluster.executors = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fault_plan_key_parses_and_round_trips() {
        let mut cfg = EngineConfig::default();
        let kv = parse_kv_str(
            "[cluster]\nfault_plan = \"panic:shuffle:0.05,seed=6\"\ntask_retries = 4\n",
        )
        .unwrap();
        cfg.apply_kv(&kv).unwrap();
        let plan = cfg.cluster.fault_plan.as_ref().unwrap();
        assert_eq!(plan.seed(), 6);
        assert_eq!(plan.to_string().parse::<crate::fault::FaultPlan>().unwrap(), *plan);
        assert_eq!(cfg.cluster.task_retries, 4);
        assert!(cfg
            .apply_kv(&parse_kv_str("[cluster]\nfault_plan = bogus\n").unwrap())
            .is_err());
    }

    #[test]
    fn memory_budget_parses_with_suffixes() {
        assert_eq!(parse_bytes("65536").unwrap(), 65_536);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("4M").unwrap(), 4 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert!(parse_bytes("lots").is_err());
        let mut cfg = EngineConfig::default();
        cfg.apply_kv(&parse_kv_str("[cluster]\nmemory_budget = \"1m\"\n").unwrap()).unwrap();
        assert_eq!(cfg.cluster.memory_budget, 1 << 20);
    }

    #[test]
    fn prefetch_depth_key_parses() {
        let mut cfg = EngineConfig::default();
        assert_eq!(cfg.cluster.prefetch_depth, 16, "prefetch is on by default");
        assert!(cfg.cluster.prefetch_adaptive, "adaptive depth is on by default");
        cfg.apply_kv(&parse_kv_str("[cluster]\nprefetch_depth = 0\n").unwrap()).unwrap();
        assert_eq!(cfg.cluster.prefetch_depth, 0);
        assert!(!cfg.cluster.prefetch_adaptive, "an explicit depth pins fixed behavior");
    }

    #[test]
    fn explicit_adaptive_survives_an_explicit_depth() {
        let mut cfg = EngineConfig::default();
        cfg.apply_kv(
            &parse_kv_str("[cluster]\nprefetch_depth = 8\nprefetch_adaptive = true\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cluster.prefetch_depth, 8);
        assert!(cfg.cluster.prefetch_adaptive, "explicit adaptive wins over the depth pin");
        cfg.apply_kv(&parse_kv_str("[cluster]\nprefetch_adaptive = false\n").unwrap()).unwrap();
        assert!(!cfg.cluster.prefetch_adaptive);
    }

    #[test]
    fn backend_parse() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("gpu".parse::<Backend>().is_err());
    }
}
