//! A small property-based testing harness (the offline build has no
//! `proptest`).
//!
//! [`run_prop`] generates `cases` random inputs from a user generator,
//! checks a property, and on failure retries with progressively "smaller"
//! regenerated inputs (shrink-by-regeneration: the generator receives a
//! shrink level that should reduce input size). Failures print the seed so
//! a case can be replayed deterministically:
//!
//! ```text
//! PROVSPARK_PROP_SEED=12345 cargo test
//! ```

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropCfg {
    /// Number of random cases to check.
    pub cases: usize,
    /// Base seed; each case uses `seed + case_index`. Overridden by the
    /// `PROVSPARK_PROP_SEED` environment variable (single-case replay).
    pub seed: u64,
    /// Maximum shrink levels attempted after a failure (each level calls
    /// the generator with a larger `shrink` argument).
    pub max_shrink_levels: u32,
}

impl Default for PropCfg {
    fn default() -> Self {
        Self { cases: 32, seed: 0xC0FFEE, max_shrink_levels: 6 }
    }
}

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Run a property over random inputs.
///
/// * `gen(rng, shrink)` — produce an input; `shrink = 0` for normal cases,
///   increasing values should produce smaller/simpler inputs.
/// * `prop(input)` — return `Err(reason)` to fail.
///
/// Panics with a replayable report on failure.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    cfg: &PropCfg,
    gen: impl Fn(&mut Pcg64, u32) -> T,
    prop: impl Fn(&T) -> PropResult,
) {
    let (seeds, replay): (Vec<u64>, bool) = match std::env::var("PROVSPARK_PROP_SEED") {
        Ok(s) => (vec![s.parse().expect("PROVSPARK_PROP_SEED must be u64")], true),
        Err(_) => ((0..cfg.cases as u64).map(|i| cfg.seed.wrapping_add(i)).collect(), false),
    };
    for seed in seeds {
        let mut rng = Pcg64::new(seed);
        let input = gen(&mut rng, 0);
        if let Err(reason) = prop(&input) {
            // Try to find a smaller failing input at higher shrink levels.
            let mut smallest: (u32, String, String) =
                (0, reason.clone(), format!("{input:?}"));
            for level in 1..=cfg.max_shrink_levels {
                let mut srng = Pcg64::new(seed ^ (level as u64) << 32);
                let small = gen(&mut srng, level);
                if let Err(r) = prop(&small) {
                    smallest = (level, r, format!("{small:?}"));
                }
            }
            let (level, r, repr) = smallest;
            let repr = if repr.len() > 2000 { format!("{}…", &repr[..2000]) } else { repr };
            panic!(
                "property {name} failed (seed={seed}, shrink_level={level}{}):\n  \
                 reason: {r}\n  input: {repr}\n  replay: PROVSPARK_PROP_SEED={seed}",
                if replay { ", replayed" } else { "" }
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop(
            "sum_commutes",
            &PropCfg::default(),
            |rng, shrink| {
                let n = if shrink > 0 { 2 } else { rng.range(0, 50) };
                (0..n).map(|_| rng.next_below(100) as i64).collect::<Vec<_>>()
            },
            |xs| {
                let mut ys = xs.clone();
                ys.reverse();
                if xs.iter().sum::<i64>() == ys.iter().sum::<i64>() {
                    Ok(())
                } else {
                    Err("sum changed under reversal".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property always_fails failed")]
    fn failing_property_reports_seed() {
        run_prop(
            "always_fails",
            &PropCfg { cases: 1, ..Default::default() },
            |rng, _| rng.next_below(10),
            |_| Err("nope".into()),
        );
    }
}
