//! The engine handle: worker pool + config + metrics, and the job runner
//! that charges the simulated per-job scheduling overhead.

use super::metrics::EngineMetrics;
use crate::config::ClusterConfig;
use crate::exec::par_map_indexed;
use std::sync::Arc;
use std::time::Duration;

/// Handle to an embedded minispark "cluster" (analogous to `SparkContext`).
///
/// Cheap to clone; all clones share the worker pool and metrics.
#[derive(Clone)]
pub struct MiniSpark {
    inner: Arc<Inner>,
}

struct Inner {
    cfg: ClusterConfig,
    metrics: EngineMetrics,
}

impl MiniSpark {
    pub fn new(cfg: ClusterConfig) -> Self {
        Self { inner: Arc::new(Inner { cfg, metrics: EngineMetrics::default() }) }
    }

    /// Default-configured engine (used by tests and examples).
    pub fn local() -> Self {
        Self::new(ClusterConfig::default())
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.inner.cfg
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.inner.metrics
    }

    /// Default partition count for new datasets.
    pub fn default_partitions(&self) -> usize {
        self.inner.cfg.default_partitions
    }

    /// Whether provably-redundant shuffles are skipped
    /// ([`ClusterConfig::shuffle_elision`]).
    pub fn elision_enabled(&self) -> bool {
        self.inner.cfg.shuffle_elision
    }

    /// Run one *job*: charge the simulated scheduling overhead, then execute
    /// `tasks` closures (one per involved partition) on the worker pool and
    /// return their outputs in order.
    ///
    /// Every public `Dataset` operation funnels through here so the job /
    /// task accounting is uniform.
    pub fn run_job<T, U, F>(&self, inputs: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        self.inner.metrics.add_job();
        self.inner.metrics.add_tasks(inputs.len() as u64);
        let overhead = self.inner.cfg.job_overhead_us;
        if overhead > 0 {
            // Models Spark driver → scheduler → executor launch latency.
            std::thread::sleep(Duration::from_micros(overhead));
        }
        par_map_indexed(inputs, self.inner.cfg.executors, f)
    }
}

impl std::fmt::Debug for MiniSpark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MiniSpark")
            .field("executors", &self.inner.cfg.executors)
            .field("default_partitions", &self.inner.cfg.default_partitions)
            .field("job_overhead_us", &self.inner.cfg.job_overhead_us)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_overhead() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn run_job_counts_and_orders() {
        let sc = no_overhead();
        let inputs: Vec<u32> = (0..10).collect();
        let out = sc.run_job(&inputs, |i, &x| (i as u32) + x);
        assert_eq!(out, (0..10).map(|x| 2 * x).collect::<Vec<_>>());
        let snap = sc.metrics().snapshot();
        assert_eq!(snap.jobs, 1);
        assert_eq!(snap.tasks, 10);
    }

    #[test]
    fn overhead_is_charged() {
        let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 5_000, ..Default::default() });
        let t0 = std::time::Instant::now();
        let _ = sc.run_job(&[1u32], |_, &x| x);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn clones_share_metrics() {
        let sc = no_overhead();
        let sc2 = sc.clone();
        let _ = sc2.run_job(&[1u32], |_, &x| x);
        assert_eq!(sc.metrics().snapshot().jobs, 1);
    }
}
