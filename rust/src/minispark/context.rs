//! The engine handle: worker pool + config + metrics, and the supervised
//! job runner that charges the simulated per-job scheduling overhead,
//! probes the fault injector, and retries panicking tasks.

use super::metrics::EngineMetrics;
use crate::config::ClusterConfig;
use crate::exec::{par_map_supervised, RetryPolicy};
use crate::fault::{FaultInjector, FaultSite};
use crate::storage::{PartitionCache, Prefetcher};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Distinguishes the spill directories of contexts sharing one process
/// (the test harness runs many in parallel under one pid).
static CONTEXT_IDS: AtomicU64 = AtomicU64::new(0);

/// Handle to an embedded minispark "cluster" (analogous to `SparkContext`).
///
/// Cheap to clone; all clones share the worker pool and metrics.
#[derive(Clone)]
pub struct MiniSpark {
    inner: Arc<Inner>,
}

struct Inner {
    cfg: ClusterConfig,
    metrics: Arc<EngineMetrics>,
    /// Armed from `cfg.fault_plan`; `None` on production configs.
    fault: Option<Arc<FaultInjector>>,
    retry: RetryPolicy,
    /// Byte-budgeted residency for spilled partitions; shares `metrics`.
    cache: Arc<PartitionCache>,
    /// Background readahead pool for frontier-driven prefetch; workers
    /// spawn lazily, so contexts that never prefetch never pay for it.
    prefetcher: Prefetcher,
    /// Lazily created directory for this context's segment files; removed
    /// (best effort) when the last clone drops.
    spill_dir: Mutex<Option<PathBuf>>,
    next_spill: AtomicU64,
    /// Runtime readahead-width controller; `None` pins the configured
    /// fixed depth ([`ClusterConfig::prefetch_adaptive`] off, or prefetch
    /// disabled outright).
    adaptive_prefetch: Option<AdaptiveDepth>,
}

/// Adapts the prefetch depth to the observed hit rate.
///
/// The controller starts at the configured depth (which doubles as the
/// cap) and re-evaluates every [`AdaptiveDepth::WINDOW`] issued prefetches
/// from the engine-wide `prefetch_issued` / `prefetch_hits` deltas: a hit
/// rate below [`AdaptiveDepth::LOW`] halves the depth (readahead is
/// warming pages the BFS never touches — shrink before it evicts useful
/// residents), above [`AdaptiveDepth::HIGH`] doubles it back toward the
/// cap. Lock-free; concurrent readers race benignly (one adjuster wins
/// the window via `compare_exchange`, the rest read the current depth).
struct AdaptiveDepth {
    depth: AtomicU64,
    cap: u64,
    /// `prefetch_issued` at the last adjustment (window claim token).
    last_issued: AtomicU64,
    /// `prefetch_hits` at the last adjustment.
    last_hits: AtomicU64,
}

impl AdaptiveDepth {
    /// Issued prefetches per adjustment window.
    const WINDOW: u64 = 64;
    /// Hit-rate floor: below this the depth halves.
    const LOW: f64 = 0.25;
    /// Hit-rate ceiling: above this the depth doubles (up to the cap).
    const HIGH: f64 = 0.75;

    fn new(cap: usize) -> Self {
        Self {
            depth: AtomicU64::new(cap as u64),
            cap: cap as u64,
            last_issued: AtomicU64::new(0),
            last_hits: AtomicU64::new(0),
        }
    }

    /// Current depth, adjusting first if a full window of issued
    /// prefetches has accumulated since the last adjustment.
    fn current(&self, metrics: &EngineMetrics) -> usize {
        let snap = metrics.snapshot();
        let seen = self.last_issued.load(Ordering::Relaxed);
        let issued = snap.prefetch_issued.saturating_sub(seen);
        if issued >= Self::WINDOW
            && self
                .last_issued
                .compare_exchange(seen, snap.prefetch_issued, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            let hits_seen = self.last_hits.swap(snap.prefetch_hits, Ordering::Relaxed);
            let hits = snap.prefetch_hits.saturating_sub(hits_seen);
            let ratio = hits as f64 / issued as f64;
            let d = self.depth.load(Ordering::Relaxed);
            let next = if ratio < Self::LOW {
                (d / 2).max(1)
            } else if ratio > Self::HIGH {
                (d * 2).min(self.cap)
            } else {
                d
            };
            if next != d {
                self.depth.store(next, Ordering::Relaxed);
            }
        }
        self.depth.load(Ordering::Relaxed) as usize
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        if let Some(dir) = self.spill_dir.get_mut().ok().and_then(|d| d.take()) {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

impl MiniSpark {
    pub fn new(cfg: ClusterConfig) -> Self {
        let fault = cfg.fault_plan.clone().map(|p| Arc::new(FaultInjector::new(p)));
        let retry =
            RetryPolicy::new(cfg.task_retries, Duration::from_micros(cfg.retry_backoff_us));
        let metrics = Arc::new(EngineMetrics::default());
        let cache = Arc::new(PartitionCache::with_metrics(cfg.memory_budget, Arc::clone(&metrics)));
        let adaptive_prefetch = (cfg.prefetch_adaptive && cfg.prefetch_depth > 0)
            .then(|| AdaptiveDepth::new(cfg.prefetch_depth));
        Self {
            inner: Arc::new(Inner {
                cfg,
                metrics,
                fault,
                retry,
                cache,
                prefetcher: Prefetcher::new(),
                spill_dir: Mutex::new(None),
                next_spill: AtomicU64::new(0),
                adaptive_prefetch,
            }),
        }
    }

    /// Default-configured engine (used by tests and examples).
    pub fn local() -> Self {
        Self::new(ClusterConfig::default())
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.inner.cfg
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.inner.metrics
    }

    /// Default partition count for new datasets.
    pub fn default_partitions(&self) -> usize {
        self.inner.cfg.default_partitions
    }

    /// Whether provably-redundant shuffles are skipped
    /// ([`ClusterConfig::shuffle_elision`]).
    pub fn elision_enabled(&self) -> bool {
        self.inner.cfg.shuffle_elision
    }

    /// The armed fault injector, if the config carries a fault plan. The
    /// `Dataset` shuffle paths probe it; callers can read its fired-fault
    /// tally for reports.
    pub fn fault(&self) -> Option<&Arc<FaultInjector>> {
        self.inner.fault.as_ref()
    }

    /// The partition cache datasets page spilled segments through. Always
    /// present; with `memory_budget == 0` nothing spills, so it stays empty.
    pub fn cache(&self) -> &Arc<PartitionCache> {
        &self.inner.cache
    }

    /// Byte budget for resident partitions; `0` means unbounded
    /// ([`ClusterConfig::memory_budget`]).
    pub fn memory_budget(&self) -> u64 {
        self.inner.cfg.memory_budget
    }

    /// The background readahead pool frontier prefetch submits jobs to.
    pub fn prefetcher(&self) -> &Prefetcher {
        &self.inner.prefetcher
    }

    /// Readahead width per BFS round: the configured
    /// [`ClusterConfig::prefetch_depth`] when that was given explicitly,
    /// otherwise the adaptive controller's current depth (hit-rate
    /// driven, capped at the configured value). `0` means prefetch is off
    /// for this context.
    pub fn prefetch_depth(&self) -> usize {
        match &self.inner.adaptive_prefetch {
            Some(ctl) => ctl.current(&self.inner.metrics),
            None => self.inner.cfg.prefetch_depth,
        }
    }

    /// A fresh path for a segment file under this context's (lazily
    /// created) spill directory. `label` names the dataset for debugging;
    /// a per-context counter keeps paths unique across respills.
    pub fn spill_path(&self, label: &str) -> anyhow::Result<PathBuf> {
        let mut dir = self.inner.spill_dir.lock().expect("spill dir lock");
        if dir.is_none() {
            let id = CONTEXT_IDS.fetch_add(1, Ordering::Relaxed);
            let d = std::env::temp_dir()
                .join(format!("provspark-spill-{}-{id}", std::process::id()));
            std::fs::create_dir_all(&d)
                .map_err(|e| anyhow::anyhow!("creating spill dir {d:?}: {e}"))?;
            *dir = Some(d);
        }
        let n = self.inner.next_spill.fetch_add(1, Ordering::Relaxed);
        Ok(dir.as_ref().expect("just created").join(format!("{label}-{n:03}.seg")))
    }

    /// Run one *job*: charge the simulated scheduling overhead, then execute
    /// `tasks` closures (one per involved partition) on the worker pool and
    /// return their outputs in order.
    ///
    /// Every task attempt runs supervised: a panic (injected or real) is
    /// caught and the task re-run up to `cfg.task_retries` times with
    /// capped exponential backoff — safe because task closures read
    /// `Arc`-shared partitions and build fresh outputs, so an abandoned
    /// attempt leaves nothing behind. A task that exhausts its budget fails
    /// the job: the panic resurfaces carrying the typed
    /// [`TaskError`](crate::exec::TaskError) message, to be caught at the
    /// harness's supervised execution boundaries.
    ///
    /// Every public `Dataset` operation funnels through here — and so does
    /// the lazy planner's stage scheduler, which submits one job per fused
    /// stage (one task per partition, however many logical ops the stage
    /// composed) — so the job / task accounting (and the fault-injection
    /// task probe) is uniform across eager and lazy execution.
    pub fn run_job<T, U, F>(&self, inputs: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        self.inner.metrics.add_job();
        self.inner.metrics.add_tasks(inputs.len() as u64);
        let overhead = self.inner.cfg.job_overhead_us;
        if overhead > 0 {
            // Models Spark driver → scheduler → executor launch latency.
            std::thread::sleep(Duration::from_micros(overhead));
        }
        let fault = self.inner.fault.as_deref();
        let (out, sup) =
            par_map_supervised(inputs, self.inner.cfg.executors, &self.inner.retry, |i, t| {
                if let Some(inj) = fault {
                    inj.fire_task(FaultSite::Task);
                }
                f(i, t)
            });
        if sup.retries > 0 {
            self.inner.metrics.add_tasks_retried(sup.retries);
        }
        out.into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(e) => panic!("{e}"),
            })
            .collect()
    }
}

impl std::fmt::Debug for MiniSpark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MiniSpark")
            .field("executors", &self.inner.cfg.executors)
            .field("default_partitions", &self.inner.cfg.default_partitions)
            .field("job_overhead_us", &self.inner.cfg.job_overhead_us)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_overhead() -> MiniSpark {
        MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() })
    }

    #[test]
    fn run_job_counts_and_orders() {
        let sc = no_overhead();
        let inputs: Vec<u32> = (0..10).collect();
        let out = sc.run_job(&inputs, |i, &x| (i as u32) + x);
        assert_eq!(out, (0..10).map(|x| 2 * x).collect::<Vec<_>>());
        let snap = sc.metrics().snapshot();
        assert_eq!(snap.jobs, 1);
        assert_eq!(snap.tasks, 10);
    }

    #[test]
    fn overhead_is_charged() {
        let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 5_000, ..Default::default() });
        let t0 = std::time::Instant::now();
        let _ = sc.run_job(&[1u32], |_, &x| x);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn clones_share_metrics() {
        let sc = no_overhead();
        let sc2 = sc.clone();
        let _ = sc2.run_job(&[1u32], |_, &x| x);
        assert_eq!(sc.metrics().snapshot().jobs, 1);
    }

    #[test]
    fn injected_task_faults_are_retried_transparently() {
        // 20% of task probes panic; 9 retries make exhausting the budget
        // (p^10 per task) impossible in practice, so the job's *answers*
        // are indistinguishable from a fault-free run.
        let cfg = ClusterConfig {
            job_overhead_us: 0,
            fault_plan: Some("panic:task:0.2,seed=9".parse().unwrap()),
            task_retries: 9,
            retry_backoff_us: 0,
            ..Default::default()
        };
        let sc = MiniSpark::new(cfg);
        let inputs: Vec<u32> = (0..64).collect();
        let out = sc.run_job(&inputs, |_, &x| x * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
        let snap = sc.metrics().snapshot();
        assert!(snap.tasks_retried > 0, "0.2 over 64+ probes must fire");
        assert_eq!(sc.fault().unwrap().fired(), snap.tasks_retried);
        assert!(snap.summary().contains("retried="));
    }

    #[test]
    fn adaptive_prefetch_tracks_the_hit_rate() {
        let sc = MiniSpark::new(ClusterConfig { prefetch_depth: 16, ..Default::default() });
        assert_eq!(sc.prefetch_depth(), 16, "starts at the cap");

        // A window of issued prefetches with zero hits: depth halves.
        sc.metrics().add_prefetch_issued(64);
        assert_eq!(sc.prefetch_depth(), 8);
        // Each consecutive cold window halves again, floored at 1.
        for _ in 0..8 {
            sc.metrics().add_prefetch_issued(64);
            sc.prefetch_depth();
        }
        assert_eq!(sc.prefetch_depth(), 1);

        // Hot windows (every issue hits) double back toward the cap…
        for _ in 0..8 {
            sc.metrics().add_prefetch_issued(64);
            for _ in 0..64 {
                sc.metrics().add_prefetch_hit();
            }
            sc.prefetch_depth();
        }
        // …and never past it.
        assert_eq!(sc.prefetch_depth(), 16);

        // A lukewarm window (between the thresholds) holds steady.
        sc.metrics().add_prefetch_issued(64);
        for _ in 0..32 {
            sc.metrics().add_prefetch_hit();
        }
        assert_eq!(sc.prefetch_depth(), 16);
    }

    #[test]
    fn explicit_depth_stays_fixed() {
        // `prefetch_adaptive: false` models an explicit `--prefetch-depth`
        // (config parsing pins it; see `config::apply_args`).
        let sc = MiniSpark::new(ClusterConfig {
            prefetch_depth: 4,
            prefetch_adaptive: false,
            ..Default::default()
        });
        sc.metrics().add_prefetch_issued(1024); // all misses
        assert_eq!(sc.prefetch_depth(), 4, "fixed depth never adapts");
    }
}
