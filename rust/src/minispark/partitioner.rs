//! Hash partitioner: key → partition index.
//!
//! Uses the SplitMix64 finalizer to scramble keys before the modulo so
//! structured key spaces (e.g. entity-prefixed attribute-value ids) spread
//! evenly — the same reason Spark's `HashPartitioner` relies on a decent
//! `hashCode`.

use crate::util::rng::mix64;

/// Maps `u64` keys to one of `num_partitions` buckets, deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPartitioner {
    num_partitions: usize,
}

impl HashPartitioner {
    pub fn new(num_partitions: usize) -> Self {
        assert!(num_partitions >= 1, "need at least one partition");
        Self { num_partitions }
    }

    #[inline]
    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    /// Partition index for `key`.
    #[inline]
    pub fn partition_of(&self, key: u64) -> usize {
        (mix64(key) % self.num_partitions as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let p = HashPartitioner::new(16);
        for k in 0..10_000u64 {
            let i = p.partition_of(k);
            assert!(i < 16);
            assert_eq!(i, p.partition_of(k));
        }
    }

    #[test]
    fn spreads_structured_keys() {
        // Entity-prefixed ids: high bits equal, low bits sequential —
        // a plain modulo would still work here, but scrambling must not
        // collapse everything into one bucket.
        let p = HashPartitioner::new(8);
        let mut counts = [0usize; 8];
        for serial in 0..8000u64 {
            let key = (5u64 << 48) | serial;
            counts[p.partition_of(key)] += 1;
        }
        for c in counts {
            assert!(c > 500, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_partition() {
        let p = HashPartitioner::new(1);
        assert_eq!(p.partition_of(12345), 0);
    }
}
