//! Hash partitioner: key → partition index.
//!
//! Uses the SplitMix64 finalizer to scramble keys before the modulo so
//! structured key spaces (e.g. entity-prefixed attribute-value ids) spread
//! evenly — the same reason Spark's `HashPartitioner` relies on a decent
//! `hashCode`.

use crate::util::rng::mix64;

/// Maps `u64` keys to one of `num_partitions` buckets, deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPartitioner {
    num_partitions: usize,
}

impl HashPartitioner {
    pub fn new(num_partitions: usize) -> Self {
        assert!(num_partitions >= 1, "need at least one partition");
        Self { num_partitions }
    }

    #[inline]
    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    /// Partition index for `key`.
    #[inline]
    pub fn partition_of(&self, key: u64) -> usize {
        (mix64(key) % self.num_partitions as u64) as usize
    }
}

/// Semantic identity of a partitioning key function.
///
/// The engine cannot compare two key closures, so elidable operations
/// (`hash_partition_by_tagged`, `reduce_values`, `join_u64`) decide
/// "already partitioned on this key" by comparing *tags*: two datasets
/// hash-partitioned with equal tags, equal partition counts and the
/// (stateless) [`HashPartitioner`] are co-partitioned — every row with a
/// given key occupies the same partition index on both, so the shuffle is
/// a no-op (Spark's narrow dependency on a matching `partitioner`).
///
/// Untagged partitionings (`hash_partition_by`) are never elided —
/// correctness over speed when the key's identity is unknown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyTag(pub u64);

impl KeyTag {
    /// The canonical key of a `(u64, V)` pair dataset: its first element.
    pub const PAIR_KEY: KeyTag = KeyTag::named("minispark.pair.0");

    /// Derive a tag from a stable name (FNV-1a), for domain key functions
    /// like "provenance triple dst" that several datasets share.
    pub const fn named(name: &str) -> KeyTag {
        let bytes = name.as_bytes();
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut i = 0;
        while i < bytes.len() {
            h ^= bytes[i] as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
            i += 1;
        }
        KeyTag(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_tags_distinguish_names() {
        assert_eq!(KeyTag::named("a"), KeyTag::named("a"));
        assert_ne!(KeyTag::named("a"), KeyTag::named("b"));
        assert_ne!(KeyTag::PAIR_KEY, KeyTag::named("prov.dst"));
    }

    #[test]
    fn deterministic_and_in_range() {
        let p = HashPartitioner::new(16);
        for k in 0..10_000u64 {
            let i = p.partition_of(k);
            assert!(i < 16);
            assert_eq!(i, p.partition_of(k));
        }
    }

    #[test]
    fn spreads_structured_keys() {
        // Entity-prefixed ids: high bits equal, low bits sequential —
        // a plain modulo would still work here, but scrambling must not
        // collapse everything into one bucket.
        let p = HashPartitioner::new(8);
        let mut counts = [0usize; 8];
        for serial in 0..8000u64 {
            let key = (5u64 << 48) | serial;
            counts[p.partition_of(key)] += 1;
        }
        for c in counts {
            assert!(c > 500, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_partition() {
        let p = HashPartitioner::new(1);
        assert_eq!(p.partition_of(12345), 0);
    }
}
