//! # minispark — an embedded, Spark-shaped dataflow engine
//!
//! The paper runs on Apache Spark 1.6.1 over an 8-node cluster. This module
//! is the substitute substrate: a partitioned, multi-threaded, in-process
//! dataflow engine whose cost structure matches the pieces of Spark the
//! paper's algorithms are sensitive to (§1 "Apache Spark"):
//!
//! * **Partitioned datasets** — a [`Dataset<T>`] is a list of immutable
//!   partitions executed in parallel by a worker pool.
//! * **Hash partitioning** — [`Dataset::hash_partition_by`] shuffles rows so
//!   all rows with the same key land in one partition; a subsequent
//!   [`Dataset::lookup`] scans exactly one partition (the paper's central
//!   cost argument for RQ/CCProv/CSProv).
//! * **Shuffle elision** — partitionings carry an optional [`KeyTag`]
//!   naming their key function; re-partitioning, `reduce_values` and
//!   `join_u64` skip the map/reduce shuffle entirely (a narrow dependency)
//!   when a dataset is already hash-partitioned on the requested tag with
//!   the requested partition count. [`EngineMetrics`] counts every elided
//!   shuffle (`shuffles_elided`) and every row saved by map-side combining
//!   (`rows_combined`), so benches can prove the savings.
//! * **filter / lookup / collect** — the three operations the paper names.
//!   `filter` scans every partition (preserving partitioning), `collect`
//!   moves all rows to the driver.
//! * **Delta ingest** — [`Dataset::append_partitioned`] routes newly
//!   arrived rows into an existing partitioned dataset by its recorded key
//!   function (copy-on-write per receiving partition), and
//!   [`Dataset::patch_partitions`] rewrites/drops rows only in the
//!   partitions owning a key set. Together they let the query engines
//!   absorb incremental preprocessing deltas
//!   ([`crate::provenance::incremental`]) without rebuilding their
//!   datasets.
//! * **Job overhead** — every operation runs as a *job* with a configurable
//!   simulated scheduling overhead ([`ClusterConfig::job_overhead_us`]),
//!   modelling Spark's job/stage launch cost. This is the effect that makes
//!   the paper's τ driver-collect optimization profitable; with overhead 0
//!   the engine degrades to a plain parallel collection library.
//! * **Metrics** — [`EngineMetrics`] counts jobs, tasks, partitions scanned,
//!   rows scanned/shuffled/collected, so experiments can report *data-volume*
//!   effects independently of wall-clock noise.
//!
//! Datasets are eager (materialized) — Spark's lazy DAG only matters for
//! fault tolerance and multi-pass optimization, neither of which the
//! paper's single-pass query algorithms exercise; caching is therefore
//! implicit (a materialized dataset *is* its cache), and `cache()` exists
//! as a documented no-op for API fidelity.

mod context;
mod dataset;
mod metrics;
mod partitioner;

pub use context::MiniSpark;
pub use dataset::{join_u64, Dataset, ScanCost};
pub use metrics::{EngineMetrics, MetricsSnapshot};
pub use partitioner::{HashPartitioner, KeyTag};
