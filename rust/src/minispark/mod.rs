//! # minispark — an embedded, Spark-shaped dataflow engine
//!
//! The paper runs on Apache Spark 1.6.1 over an 8-node cluster. This module
//! is the substitute substrate: a partitioned, multi-threaded, in-process
//! dataflow engine whose cost structure matches the pieces of Spark the
//! paper's algorithms are sensitive to (§1 "Apache Spark"):
//!
//! * **Partitioned datasets** — a [`Dataset<T>`] is a list of immutable
//!   partitions executed in parallel by a worker pool.
//! * **Hash partitioning** — [`Dataset::hash_partition_by`] shuffles rows so
//!   all rows with the same key land in one partition; a subsequent
//!   [`Dataset::lookup`] scans exactly one partition (the paper's central
//!   cost argument for RQ/CCProv/CSProv).
//! * **Shuffle elision** — partitionings carry an optional [`KeyTag`]
//!   naming their key function; re-partitioning, `reduce_values` and
//!   `join_u64` skip the map/reduce shuffle entirely (a narrow dependency)
//!   when a dataset is already hash-partitioned on the requested tag with
//!   the requested partition count. [`EngineMetrics`] counts every elided
//!   shuffle (`shuffles_elided`) and every row saved by map-side combining
//!   (`rows_combined`), so benches can prove the savings.
//! * **filter / lookup / collect** — the three operations the paper names.
//!   `filter` scans every partition (preserving partitioning), `collect`
//!   moves all rows to the driver.
//! * **Delta ingest** — [`Dataset::append_partitioned`] routes newly
//!   arrived rows into an existing partitioned dataset by its recorded key
//!   function (copy-on-write per receiving partition), and
//!   [`Dataset::patch_partitions`] rewrites/drops rows only in the
//!   partitions owning a key set. Together they let the query engines
//!   absorb incremental preprocessing deltas
//!   ([`crate::provenance::incremental`]) without rebuilding their
//!   datasets.
//! * **Job overhead** — every operation runs as a *job* with a configurable
//!   simulated scheduling overhead ([`ClusterConfig::job_overhead_us`]),
//!   modelling Spark's job/stage launch cost. This is the effect that makes
//!   the paper's τ driver-collect optimization profitable; with overhead 0
//!   the engine degrades to a plain parallel collection library.
//! * **Metrics** — [`EngineMetrics`] counts jobs, tasks, partitions scanned,
//!   rows scanned/shuffled/collected, so experiments can report *data-volume*
//!   effects independently of wall-clock noise.
//!
//! Execution is **lazy at the plan layer and eager at the dataset layer**.
//! A [`Dataset<T>`] is always materialized (so a dataset *is* its cache and
//! `cache()` is a documented no-op kept for API fidelity), but
//! [`Dataset::lazy`] lifts it into a [`LazyDataset`] logical plan: narrow
//! ops (`filter`/`map`/`map_partitions`/`append_rows`) fuse into a single
//! pass per stage, shuffles cut stages, and provably-elided re-partitions
//! (the [`KeyTag`] machinery) fuse straight through. Nothing runs until an
//! explicit `materialize()`/`collect()` boundary forces the plan through
//! the ordinary job scheduler — same pool, fault probes, and demand-paged
//! partition cache as the eager ops. [`EngineMetrics`] counts the stages
//! (`stages_run`), the ops folded into them (`ops_fused`), and the
//! intermediate rows fusion never materialized (`intermediates_avoided`);
//! `rust/tests/dag_props.rs` holds the differential proof that lazy and
//! eager execution agree on results and shuffle metrics.

mod context;
mod dataset;
mod metrics;
mod partitioner;
mod plan;

pub use context::MiniSpark;
pub use dataset::{join_u64, Dataset, ScanCost};
pub use metrics::{EngineMetrics, MetricsSnapshot};
pub use partitioner::{HashPartitioner, KeyTag};
pub use plan::{lazy_join_u64, LazyDataset, StageCost};
