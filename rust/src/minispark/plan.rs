//! Lazy logical plans, stage fusion, and the stage scheduler.
//!
//! [`Dataset::lazy`] lifts a materialized dataset into a [`LazyDataset`]:
//! a DAG node whose transformations *record* work instead of executing it.
//! The planner decides stage boundaries at plan-build time:
//!
//! * **Narrow ops fuse.** `filter`/`map`/`flat_map`/`map_partitions`/
//!   `map_values`/`append_rows` (and the already-co-located
//!   `reduce_values`) extend the pending stage: their per-partition
//!   closures compose, so the whole chain runs as **one pass** over the
//!   stage's input partitions, never allocating the intermediate rows an
//!   eager chain materializes between ops.
//! * **Shuffles cut.** `hash_partition_by`, a non-elidable tagged
//!   re-partition, `reduce_by_key`, `union`, and [`lazy_join_u64`] start a
//!   new stage. The wide op itself executes through the *eager* dataset
//!   code path when the node is forced, so shuffle metering
//!   (`rows_shuffled`, `shuffles_elided`, map-side combine) is identical
//!   to eager execution by construction.
//! * **Provably-elided shuffles fuse.** A tagged re-partition whose
//!   [`KeyTag`] and partition count match the plan's tracked partitioning
//!   is a no-op exactly when the eager engine would elide it (the PR 1
//!   machinery), so it does **not** cut — the chain above and below it
//!   stays one stage.
//!
//! Forcing a node ([`LazyDataset::materialize`], `collect`, `count`) runs
//! its stages through the ordinary [`MiniSpark::run_job`] scheduler: the
//! same executor pool, the same per-task `FaultSite::Task` probes, and —
//! because a stage materializes its input via the demand-paging
//! [`Dataset::partition`] path — the same byte-budgeted `PartitionCache`.
//! Each node memoizes its output, so shared sub-plans and repeated
//! `materialize()` calls execute once.
//!
//! What is intentionally *not* identical to eager execution: job/task
//! counts (a fused chain is one job, not one per op), `rows_scanned` /
//! `partitions_scanned` (charged once per stage, not once per logical op
//! — the double-count the eager chains carry), and the exact fault-draw
//! sequence (fused appends probe `FaultSite::Task`, not
//! `FaultSite::Shuffle`). Results, `rows_shuffled`, and `shuffles_elided`
//! are bit-identical — `rust/tests/dag_props.rs` proves it.
//!
//! [`KeyTag`]: super::KeyTag

use super::context::MiniSpark;
use super::dataset::{Dataset, Partitioning, ScanCost};
use super::partitioner::{HashPartitioner, KeyTag};
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// A fused per-partition operator: `(partition index, input rows) → output
/// rows`. The index lets partition-addressed ops (append) fuse too.
type PartOp<S, U> = Arc<dyn Fn(usize, &[S]) -> Vec<U> + Send + Sync>;

/// Runs one fused stage over input partition `i`.
type StageRun<T> = Arc<dyn Fn(usize) -> Vec<T> + Send + Sync>;

/// Produces a node's dataset at a stage boundary (pre-materialized source
/// or an eager wide op).
type SourceFn<T> = Box<dyn Fn() -> Dataset<T> + Send + Sync>;

/// Composes a narrow node's pending chain into an executable stage.
type BuildFn<T> = Box<dyn Fn() -> FusedStage<T> + Send + Sync>;

/// Total [`StageCost`] of everything upstream of a node.
type CostFn = Box<dyn Fn() -> StageCost + Send + Sync>;

/// Per-plan cost of the fused stages a `*_counted` action executed (or
/// replayed from the plan's memo): deterministic per plan, so callers can
/// attribute data-volume costs to one query even when batched queries
/// share the memoized node (the engine-wide ledger then shows the saved
/// scans; this does not).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageCost {
    /// Fused stages executed.
    pub stages: u64,
    /// Logical ops those stages covered.
    pub ops: u64,
    /// Ops folded into an already-pending stage (`ops - stages` for a
    /// straight chain).
    pub fused: u64,
    /// Intermediate rows eager execution would have materialized between
    /// fused ops.
    pub intermediates_avoided: u64,
    /// The stages' input scan volume and cache traffic.
    pub scan: ScanCost,
}

impl StageCost {
    /// Accumulate another plan fragment's cost.
    pub fn accum(&mut self, other: StageCost) {
        self.stages += other.stages;
        self.ops += other.ops;
        self.fused += other.fused;
        self.intermediates_avoided += other.intermediates_avoided;
        self.scan.add(other.scan);
    }
}

/// One executable stage: the composed per-partition closure plus the
/// metering captured when the stage's input was pinned.
struct FusedStage<T> {
    run: StageRun<T>,
    num_partitions: usize,
    input_partitions: u64,
    input_rows: u64,
    cache_hits: u64,
    cache_misses: u64,
    /// Logical ops fused into this stage.
    ops: u64,
    /// Rows crossing fused op boundaries, counted while the stage runs.
    /// Retried tasks re-count their partition — the counter is a metric,
    /// not part of the result.
    intermediates: Arc<AtomicU64>,
}

enum NodeKind<T> {
    /// Stage boundary: a pre-materialized dataset or an eager wide op.
    Source(SourceFn<T>),
    /// A fusable narrow chain, composed into one stage when forced.
    Narrow(BuildFn<T>),
}

struct NodeInner<T> {
    kind: NodeKind<T>,
    /// Memoized output: every node materializes at most once.
    out: OnceLock<Dataset<T>>,
    /// Cost of the stage this node ran (set only on nodes forced as a
    /// chain tail; interior nodes of a fused chain stay empty because the
    /// tail's stage covers them).
    own_cost: OnceLock<StageCost>,
    upstream: CostFn,
    /// The partitioning the materialized output will carry, decided at
    /// plan time by mirroring the eager ops' partitioning rules.
    spec: Option<Partitioning<T>>,
}

/// How a plan's logical ops were grouped into stages — the planner's
/// explainable output, compared verbatim by plan-shape tests.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
struct PlanShape {
    stages: Vec<StageShape>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct StageShape {
    /// Why this stage could not fuse into the previous one (`None` for the
    /// leading stage of a plan).
    cut: Option<String>,
    ops: Vec<String>,
}

impl PlanShape {
    fn source(label: &str) -> Self {
        Self { stages: vec![StageShape { cut: None, ops: vec![label.to_string()] }] }
    }

    /// The op fused into the pending stage.
    fn pushed(&self, op: &str) -> Self {
        let mut s = self.clone();
        s.stages.last_mut().expect("plans always have a stage").ops.push(op.to_string());
        s
    }

    /// The op started a new stage.
    fn cut(&self, op: &str, reason: &str) -> Self {
        let mut s = self.clone();
        s.stages
            .push(StageShape { cut: Some(reason.to_string()), ops: vec![op.to_string()] });
        s
    }

    /// Two plans met at a barrier op (union, join).
    fn merged(a: &PlanShape, b: &PlanShape, op: &str, reason: &str) -> Self {
        let mut stages = a.stages.clone();
        stages.extend(b.stages.iter().cloned());
        stages.push(StageShape { cut: Some(reason.to_string()), ops: vec![op.to_string()] });
        Self { stages }
    }

    fn render(&self) -> String {
        let mut out = String::new();
        for (i, st) in self.stages.iter().enumerate() {
            match &st.cut {
                Some(r) => out.push_str(&format!("stage {i} [{r}]: ")),
                None => out.push_str(&format!("stage {i}: ")),
            }
            out.push_str(&st.ops.join(" → "));
            out.push('\n');
        }
        out
    }
}

/// A lazy, partitioned dataset: a node in the logical-plan DAG.
///
/// Transformations build plan nodes; nothing executes until an action
/// ([`materialize`](Self::materialize), [`collect`](Self::collect),
/// [`count`](Self::count)) forces the node. See the [module
/// docs](self) for the fusion and cut rules.
///
/// ```
/// use provspark::config::ClusterConfig;
/// use provspark::minispark::{Dataset, MiniSpark};
///
/// let sc = MiniSpark::new(ClusterConfig { job_overhead_us: 0, ..Default::default() });
/// let d = Dataset::from_vec(&sc, (0..100u64).collect(), 8);
/// let mut out = d.lazy().filter(|&x| x % 2 == 0).map(|&x| x * 10).collect();
/// out.sort_unstable();
/// assert_eq!(out.len(), 50);
/// let m = sc.metrics().snapshot();
/// assert_eq!(m.stages_run, 1); // filter + map fused into one pass
/// assert_eq!(m.ops_fused, 1);
/// assert_eq!(m.intermediates_avoided, 50); // the filtered rows never materialized
/// ```
pub struct LazyDataset<T> {
    sc: MiniSpark,
    node: Arc<NodeInner<T>>,
    shape: PlanShape,
}

impl<T> Clone for LazyDataset<T> {
    fn clone(&self) -> Self {
        Self { sc: self.sc.clone(), node: Arc::clone(&self.node), shape: self.shape.clone() }
    }
}

impl<T: Send + Sync + Clone + 'static> Dataset<T> {
    /// Lift this dataset into a lazy plan rooted at it. The root is
    /// already materialized, so the first narrow op starts a fresh stage
    /// over these partitions.
    pub fn lazy(&self) -> LazyDataset<T> {
        let spec = self.partitioning().cloned();
        let ds = self.clone();
        let out = OnceLock::new();
        let _ = out.set(self.clone());
        LazyDataset {
            sc: self.context().clone(),
            node: Arc::new(NodeInner {
                kind: NodeKind::Source(Box::new(move || ds.clone())),
                out,
                own_cost: OnceLock::new(),
                upstream: Box::new(StageCost::default),
                spec,
            }),
            shape: PlanShape::source("source"),
        }
    }
}

/// Pin a materialized dataset's partitions and wrap `op` over them — the
/// first op of a fresh stage.
fn leaf_stage<S, U>(ds: &Dataset<S>, op: PartOp<S, U>) -> FusedStage<U>
where
    S: Send + Sync + Clone + 'static,
    U: Send + Sync + Clone + 'static,
{
    let input = Arc::new(ds.stage_input());
    let np = input.num_partitions();
    let input_rows = input.total_rows();
    let (cache_hits, cache_misses) = input.cache_touch();
    let run: StageRun<U> = Arc::new(move |i| op(i, input.rows(i)));
    FusedStage {
        run,
        num_partitions: np,
        input_partitions: np as u64,
        input_rows,
        cache_hits,
        cache_misses,
        ops: 1,
        intermediates: Arc::new(AtomicU64::new(0)),
    }
}

/// Fuse `op` onto a pending stage: the composed closure pipes partition
/// `i` through the parent chain, counts the rows that would have been an
/// eager intermediate, and applies `op` — no allocation survives between
/// ops beyond the one transient `Vec`.
fn extend_stage<S, U>(parent: FusedStage<S>, op: PartOp<S, U>) -> FusedStage<U>
where
    S: Send + Sync + Clone + 'static,
    U: Send + Sync + Clone + 'static,
{
    let FusedStage {
        run: prun,
        num_partitions,
        input_partitions,
        input_rows,
        cache_hits,
        cache_misses,
        ops,
        intermediates,
    } = parent;
    let ctr = Arc::clone(&intermediates);
    let run: StageRun<U> = Arc::new(move |i| {
        let mid = prun(i);
        ctr.fetch_add(mid.len() as u64, Ordering::Relaxed);
        op(i, &mid)
    });
    FusedStage {
        run,
        num_partitions,
        input_partitions,
        input_rows,
        cache_hits,
        cache_misses,
        ops: ops + 1,
        intermediates,
    }
}

/// Build the stage that materializes `op` over `parent`: extend the
/// parent's pending chain, or start a fresh stage over its (possibly
/// just-forced) output.
fn compose<S, U>(
    sc: &MiniSpark,
    parent: &Arc<NodeInner<S>>,
    op: &PartOp<S, U>,
) -> FusedStage<U>
where
    S: Send + Sync + Clone + 'static,
    U: Send + Sync + Clone + 'static,
{
    if let Some(ds) = parent.out.get() {
        return leaf_stage(ds, Arc::clone(op));
    }
    match &parent.kind {
        NodeKind::Narrow(build) => extend_stage(build(), Arc::clone(op)),
        NodeKind::Source(_) => leaf_stage(&force(sc, parent), Arc::clone(op)),
    }
}

/// Execute one fused stage through the ordinary job scheduler: one
/// `add_scan` for the stage's input (rows are charged once per stage, not
/// once per logical op), one job whose tasks carry the usual fault probes,
/// then the stage counters.
fn run_stage<T>(
    sc: &MiniSpark,
    stage: FusedStage<T>,
    spec: Option<Partitioning<T>>,
) -> (Dataset<T>, StageCost)
where
    T: Send + Sync + Clone + 'static,
{
    sc.metrics().add_scan(stage.input_partitions, stage.input_rows);
    let indices: Vec<usize> = (0..stage.num_partitions).collect();
    let run = Arc::clone(&stage.run);
    let partitions: Vec<Arc<Vec<T>>> = sc.run_job(&indices, |_, &i| Arc::new(run(i)));
    let intermediates = stage.intermediates.load(Ordering::Relaxed);
    sc.metrics().add_stage(stage.ops, intermediates);
    let cost = StageCost {
        stages: 1,
        ops: stage.ops,
        fused: stage.ops - 1,
        intermediates_avoided: intermediates,
        scan: ScanCost {
            partitions: stage.input_partitions,
            rows: stage.input_rows,
            cache_hits: stage.cache_hits,
            cache_misses: stage.cache_misses,
        },
    };
    drop(run);
    drop(stage); // release the input pins only after the pass completes
    (Dataset::from_stage(sc, partitions, spec), cost)
}

/// Materialize a node, memoized: sources run their (eager) producer, narrow
/// chains compose and run as one stage.
fn force<T>(sc: &MiniSpark, node: &Arc<NodeInner<T>>) -> Dataset<T>
where
    T: Send + Sync + Clone + 'static,
{
    node.out
        .get_or_init(|| match &node.kind {
            NodeKind::Source(make) => make(),
            NodeKind::Narrow(build) => {
                let (ds, cost) = run_stage(sc, build(), node.spec.clone());
                let _ = node.own_cost.set(cost);
                ds
            }
        })
        .clone()
}

/// Closure reporting `node`'s total cost (its upstream plus its own stage,
/// if it ran one) — evaluated after forcing, captured at plan-build time.
fn upstream_of<S>(node: &Arc<NodeInner<S>>) -> CostFn
where
    S: Send + Sync + Clone + 'static,
{
    let p = Arc::clone(node);
    Box::new(move || {
        let mut c = (p.upstream)();
        if let Some(own) = p.own_cost.get() {
            c.accum(*own);
        }
        c
    })
}

/// Total cost of the fused stages that materialized (or would replay for)
/// this node.
fn total_cost<T>(node: &NodeInner<T>) -> StageCost {
    let mut c = (node.upstream)();
    if let Some(own) = node.own_cost.get() {
        c.accum(*own);
    }
    c
}

impl<T: Send + Sync + Clone + 'static> LazyDataset<T> {
    fn narrow<U: Send + Sync + Clone + 'static>(
        &self,
        name: &str,
        spec: Option<Partitioning<U>>,
        op: impl Fn(usize, &[T]) -> Vec<U> + Send + Sync + 'static,
    ) -> LazyDataset<U> {
        let op: PartOp<T, U> = Arc::new(op);
        let parent = Arc::clone(&self.node);
        let sc = self.sc.clone();
        let build: BuildFn<U> = Box::new(move || compose(&sc, &parent, &op));
        LazyDataset {
            sc: self.sc.clone(),
            node: Arc::new(NodeInner {
                kind: NodeKind::Narrow(build),
                out: OnceLock::new(),
                own_cost: OnceLock::new(),
                upstream: upstream_of(&self.node),
                spec,
            }),
            shape: self.shape.pushed(name),
        }
    }

    fn cut_node<U: Send + Sync + Clone + 'static>(
        &self,
        shape: PlanShape,
        spec: Option<Partitioning<U>>,
        upstream: CostFn,
        make: impl Fn() -> Dataset<U> + Send + Sync + 'static,
    ) -> LazyDataset<U> {
        LazyDataset {
            sc: self.sc.clone(),
            node: Arc::new(NodeInner {
                kind: NodeKind::Source(Box::new(make)),
                out: OnceLock::new(),
                own_cost: OnceLock::new(),
                upstream,
                spec,
            }),
            shape,
        }
    }

    /// Plan-time mirror of [`Dataset::partitioned_on`]: would the
    /// materialized plan provably already be partitioned on `tag`?
    fn spec_partitioned_on(&self, tag: KeyTag, num_partitions: usize) -> bool {
        self.sc.elision_enabled()
            && matches!(
                &self.node.spec,
                Some(p) if p.key_tag == Some(tag)
                    && p.partitioner.num_partitions() == num_partitions
            )
    }

    /// Narrow: fuses. Preserves the plan's partitioning (filter never
    /// moves rows).
    pub fn filter(&self, pred: impl Fn(&T) -> bool + Send + Sync + 'static) -> Self {
        let spec = self.node.spec.clone();
        self.narrow("filter", spec, move |_, part| {
            part.iter().filter(|r| pred(r)).cloned().collect()
        })
    }

    /// Narrow: fuses. Drops partitioning (keys may change).
    pub fn map<U: Send + Sync + Clone + 'static>(
        &self,
        f: impl Fn(&T) -> U + Send + Sync + 'static,
    ) -> LazyDataset<U> {
        self.narrow("map", None, move |_, part| part.iter().map(&f).collect())
    }

    /// Narrow: fuses. Drops partitioning.
    pub fn flat_map<U: Send + Sync + Clone + 'static>(
        &self,
        f: impl Fn(&T) -> Vec<U> + Send + Sync + 'static,
    ) -> LazyDataset<U> {
        self.narrow("flat_map", None, move |_, part| part.iter().flat_map(&f).collect())
    }

    /// Narrow: fuses. Drops partitioning.
    pub fn map_partitions<U: Send + Sync + Clone + 'static>(
        &self,
        f: impl Fn(&[T]) -> Vec<U> + Send + Sync + 'static,
    ) -> LazyDataset<U> {
        self.narrow("map_partitions", None, move |_, part| f(part))
    }

    /// Lazy [`Dataset::append_partitioned`]: rows are bucketed by the
    /// plan's partitioning at plan time (metered as shuffled, exactly like
    /// the eager driver-side bucketing) and the per-partition extend fuses
    /// into the pending stage.
    ///
    /// Panics if the plan is not hash-partitioned.
    pub fn append_rows(&self, rows: &[T]) -> Self {
        let spec = self.node.spec.clone();
        let p = spec
            .as_ref()
            .expect("append_rows() requires a hash-partitioned plan");
        if rows.is_empty() {
            return self.clone();
        }
        let np = p.partitioner.num_partitions();
        let mut buckets: Vec<Vec<T>> = (0..np).map(|_| Vec::new()).collect();
        for r in rows {
            buckets[p.partitioner.partition_of((p.key_fn)(r))].push(r.clone());
        }
        self.sc.metrics().add_shuffled(rows.len() as u64);
        let buckets = Arc::new(buckets);
        self.narrow("append", spec, move |i, part| {
            let mut v = Vec::with_capacity(part.len() + buckets[i].len());
            v.extend_from_slice(part);
            v.extend_from_slice(&buckets[i]);
            v
        })
    }

    /// Wide: cuts a stage. The shuffle executes eagerly when forced, so
    /// its metering matches [`Dataset::hash_partition_by`] exactly.
    pub fn hash_partition_by(
        &self,
        num_partitions: usize,
        key_fn: impl Fn(&T) -> u64 + Send + Sync + 'static,
    ) -> Self {
        self.shuffle_cut(num_partitions, None, Arc::new(key_fn))
    }

    /// Tagged re-partition: **elided at plan time** — no cut, no job, one
    /// `shuffles_elided` tick — when the plan is provably already
    /// partitioned on `tag` (mirroring
    /// [`Dataset::hash_partition_by_tagged`]); otherwise a stage cut.
    pub fn hash_partition_by_tagged(
        &self,
        num_partitions: usize,
        tag: KeyTag,
        key_fn: impl Fn(&T) -> u64 + Send + Sync + 'static,
    ) -> Self {
        let np = num_partitions.max(1);
        if self.spec_partitioned_on(tag, np) {
            self.sc.metrics().add_elided();
            return Self {
                sc: self.sc.clone(),
                node: Arc::clone(&self.node),
                shape: self.shape.pushed("repartition(elided)"),
            };
        }
        self.shuffle_cut(np, Some(tag), Arc::new(key_fn))
    }

    fn shuffle_cut(
        &self,
        num_partitions: usize,
        tag: Option<KeyTag>,
        key_fn: Arc<dyn Fn(&T) -> u64 + Send + Sync>,
    ) -> Self {
        let np = num_partitions.max(1);
        // The spec shares the key_fn Arc with the shuffle, so downstream
        // identity checks (union co-partitioning) see one closure.
        let spec = Some(Partitioning {
            partitioner: HashPartitioner::new(np),
            key_fn: Arc::clone(&key_fn),
            key_tag: tag,
        });
        let parent = Arc::clone(&self.node);
        let sc = self.sc.clone();
        self.cut_node(
            self.shape.cut("repartition", "shuffle(partition)"),
            spec,
            upstream_of(&self.node),
            move || force(&sc, &parent).shuffle_partition(np, tag, Arc::clone(&key_fn)),
        )
    }

    /// Wide: cuts a stage; the shuffle-reduce (with map-side combine) runs
    /// eagerly when forced, metering exactly like
    /// [`Dataset::reduce_by_key`].
    pub fn reduce_by_key<V: Send + Sync + Clone + 'static>(
        &self,
        num_partitions: usize,
        kv: impl Fn(&T) -> (u64, V) + Send + Sync + 'static,
        red: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> LazyDataset<(u64, V)> {
        let np = num_partitions.max(1);
        let spec = Some(Partitioning {
            partitioner: HashPartitioner::new(np),
            key_fn: Arc::new(|r: &(u64, V)| r.0),
            key_tag: Some(KeyTag::PAIR_KEY),
        });
        let parent = Arc::clone(&self.node);
        let sc = self.sc.clone();
        self.cut_node(
            self.shape.cut("reduce_by_key", "shuffle(aggregation)"),
            spec,
            upstream_of(&self.node),
            move || force(&sc, &parent).reduce_by_key(np, &kv, &red),
        )
    }

    /// Barrier over two plans; the concatenation itself is the eager
    /// driver-side [`Dataset::union`] (co-partitioned inputs keep their
    /// partitioning — the plan tracks the same rule).
    pub fn union(&self, other: &LazyDataset<T>) -> Self {
        let spec = match (&self.node.spec, &other.node.spec) {
            (Some(a), Some(b))
                if a.partitioner == b.partitioner
                    && (Arc::ptr_eq(&a.key_fn, &b.key_fn)
                        || (a.key_tag.is_some() && a.key_tag == b.key_tag)) =>
            {
                self.node.spec.clone()
            }
            _ => None,
        };
        let pa = Arc::clone(&self.node);
        let pb = Arc::clone(&other.node);
        let sc = self.sc.clone();
        let ua = upstream_of(&self.node);
        let ub = upstream_of(&other.node);
        let upstream: CostFn = Box::new(move || {
            let mut c = ua();
            c.accum(ub());
            c
        });
        self.cut_node(
            PlanShape::merged(&self.shape, &other.shape, "union", "barrier(union)"),
            spec,
            upstream,
            move || force(&sc, &pa).union(&force(&sc, &pb)),
        )
    }

    /// Force the plan and return the materialized dataset — the explicit
    /// lazy/eager boundary. Memoized: a second call (or a second plan
    /// sharing this node) returns the same datasets without re-running.
    pub fn materialize(&self) -> Dataset<T> {
        force(&self.sc, &self.node)
    }

    /// [`materialize`](Self::materialize) plus the plan's [`StageCost`]
    /// for per-query attribution. The cost is deterministic per plan: a
    /// memoized re-materialization replays the recorded cost even though
    /// the engine-wide ledger shows no new scan.
    pub fn materialize_counted(&self) -> (Dataset<T>, StageCost) {
        let ds = force(&self.sc, &self.node);
        (ds, total_cost(&self.node))
    }

    /// Force the plan and collect every row to the driver (metered like
    /// the eager [`Dataset::collect`]).
    pub fn collect(&self) -> Vec<T> {
        self.materialize().collect()
    }

    /// [`collect`](Self::collect) with the plan's [`StageCost`].
    pub fn collect_counted(&self) -> (Vec<T>, StageCost) {
        let (ds, cost) = self.materialize_counted();
        (ds.collect(), cost)
    }

    /// Force the plan and count rows (an action, like the eager
    /// [`Dataset::count`]).
    pub fn count(&self) -> usize {
        self.materialize().count()
    }

    /// Stages the planner cut this plan into (elided re-partitions do not
    /// count — they fused).
    pub fn num_stages(&self) -> usize {
        self.shape.stages.len()
    }

    /// Human-readable plan: one line per stage with its fused op chain and
    /// the cut reason that started it — what plan-shape tests diff.
    pub fn explain(&self) -> String {
        self.shape.render().trim_end().to_string()
    }
}

/// Pair-dataset fast paths, mirroring the eager `Dataset<(u64, V)>` impl.
impl<V: Send + Sync + Clone + 'static> LazyDataset<(u64, V)> {
    /// Tagged re-partition on the pair key — elided (fused through)
    /// whenever the plan is already key-partitioned.
    pub fn partition_by_key(&self, num_partitions: usize) -> Self {
        self.hash_partition_by_tagged(num_partitions, KeyTag::PAIR_KEY, |r| r.0)
    }

    /// Narrow: fuses. Keeps key-partitioning when the plan is
    /// [`KeyTag::PAIR_KEY`]-partitioned (mirroring
    /// [`Dataset::map_values`]).
    pub fn map_values<U: Send + Sync + Clone + 'static>(
        &self,
        f: impl Fn(&V) -> U + Send + Sync + 'static,
    ) -> LazyDataset<(u64, U)> {
        let spec = match &self.node.spec {
            Some(p) if p.key_tag == Some(KeyTag::PAIR_KEY) => Some(Partitioning {
                partitioner: p.partitioner,
                key_fn: Arc::new(|r: &(u64, U)| r.0),
                key_tag: Some(KeyTag::PAIR_KEY),
            }),
            _ => None,
        };
        self.narrow("map_values", spec, move |_, part| {
            part.iter().map(|(k, v)| (*k, f(v))).collect()
        })
    }

    /// [`Dataset::reduce_values`], planned: when the plan is provably
    /// key-partitioned the per-partition combine **fuses** into the
    /// pending stage (elided, zero shuffle — the narrow dependency);
    /// otherwise it falls back to the shuffling
    /// [`reduce_by_key`](Self::reduce_by_key) cut.
    pub fn reduce_values(
        &self,
        num_partitions: usize,
        red: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> LazyDataset<(u64, V)> {
        let np = num_partitions.max(1);
        if self.spec_partitioned_on(KeyTag::PAIR_KEY, np) {
            self.sc.metrics().add_elided();
            let spec = Some(Partitioning {
                partitioner: HashPartitioner::new(np),
                key_fn: Arc::new(|r: &(u64, V)| r.0),
                key_tag: Some(KeyTag::PAIR_KEY),
            });
            return self.narrow("reduce_values", spec, move |_, part| {
                let mut acc: FxHashMap<u64, V> = FxHashMap::default();
                for (k, v) in part {
                    super::dataset::combine_into(&mut acc, *k, v.clone(), &red);
                }
                acc.into_iter().collect()
            });
        }
        self.reduce_by_key(np, |r| (r.0, r.1.clone()), red)
    }
}

/// Lazy [`join_u64`](super::join_u64): a barrier cut over both plans; the
/// co-partitioned hash join itself runs eagerly when forced, so per-side
/// shuffle/elision metering matches the eager join exactly.
pub fn lazy_join_u64<V1, V2>(
    left: &LazyDataset<(u64, V1)>,
    right: &LazyDataset<(u64, V2)>,
    num_partitions: usize,
) -> LazyDataset<(u64, (V1, V2))>
where
    V1: Send + Sync + Clone + 'static,
    V2: Send + Sync + Clone + 'static,
{
    let np = num_partitions.max(1);
    let spec = Some(Partitioning {
        partitioner: HashPartitioner::new(np),
        key_fn: Arc::new(|r: &(u64, (V1, V2))| r.0),
        key_tag: Some(KeyTag::PAIR_KEY),
    });
    let pa = Arc::clone(&left.node);
    let pb = Arc::clone(&right.node);
    let sc = left.sc.clone();
    let ua = upstream_of(&left.node);
    let ub = upstream_of(&right.node);
    let upstream: CostFn = Box::new(move || {
        let mut c = ua();
        c.accum(ub());
        c
    });
    left.cut_node(
        PlanShape::merged(&left.shape, &right.shape, "join", "shuffle(join)"),
        spec,
        upstream,
        move || super::dataset::join_u64(&force(&sc, &pa), &force(&sc, &pb), np),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn sc() -> MiniSpark {
        MiniSpark::new(ClusterConfig {
            executors: 4,
            default_partitions: 8,
            job_overhead_us: 0,
            shuffle_elision: true,
            ..Default::default()
        })
    }

    fn pairs(n: u64, keys: u64) -> Vec<(u64, u64)> {
        (0..n).map(|i| (i % keys, i)).collect()
    }

    // ---- plan shape: the planner's cut/fuse decisions, diffed verbatim ----

    #[test]
    fn narrow_chain_is_one_stage() {
        let s = sc();
        let d = Dataset::from_vec(&s, (0..100u64).collect(), 8);
        let plan = d
            .lazy()
            .filter(|&x| x % 2 == 0)
            .map(|&x| x + 1)
            .map_partitions(|p| p.to_vec());
        assert_eq!(
            plan.explain(),
            "stage 0: source → filter → map → map_partitions",
            "plan:\n{}",
            plan.explain()
        );
        assert_eq!(plan.num_stages(), 1);
        let mut got = plan.collect();
        got.sort_unstable();
        let want: Vec<u64> = (0..100).filter(|x| x % 2 == 0).map(|x| x + 1).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn tagged_repartition_on_same_key_fuses_instead_of_cutting() {
        let s = sc();
        let d = Dataset::from_vec(&s, pairs(200, 13), 8).partition_by_key(8);
        let before = s.metrics().snapshot();
        let plan = d.lazy().filter(|r| r.1 % 3 != 0).partition_by_key(8);
        assert_eq!(
            plan.explain(),
            "stage 0: source → filter → repartition(elided)",
            "plan:\n{}",
            plan.explain()
        );
        assert_eq!(plan.num_stages(), 1, "an elided shuffle must not cut a stage");
        let delta = s.metrics().snapshot().since(&before);
        assert_eq!(delta.shuffles_elided, 1, "the elision is metered at plan time");
        assert_eq!(delta.rows_shuffled, 0);
    }

    #[test]
    fn untagged_join_cuts_a_stage() {
        let s = sc();
        let l = Dataset::from_vec(&s, pairs(100, 7), 4).lazy().filter(|r| r.0 != 1);
        let r = Dataset::from_vec(&s, pairs(60, 7), 4).lazy();
        let j = lazy_join_u64(&l, &r, 4);
        assert_eq!(
            j.explain(),
            "stage 0: source → filter\nstage 1: source\nstage 2 [shuffle(join)]: join",
            "plan:\n{}",
            j.explain()
        );
        assert_eq!(j.num_stages(), 3);
        // Results (and shuffle volume) equal the eager join.
        let before = s.metrics().snapshot();
        let mut lazy_rows = j.collect();
        let lazy_shuffled = s.metrics().snapshot().since(&before).rows_shuffled;
        let el = Dataset::from_vec(&s, pairs(100, 7), 4).filter(|r| r.0 != 1);
        let er = Dataset::from_vec(&s, pairs(60, 7), 4);
        let before = s.metrics().snapshot();
        let mut eager_rows = super::super::dataset::join_u64(&el, &er, 4).collect();
        let eager_shuffled = s.metrics().snapshot().since(&before).rows_shuffled;
        lazy_rows.sort_unstable();
        eager_rows.sort_unstable();
        assert_eq!(lazy_rows, eager_rows);
        assert_eq!(lazy_shuffled, eager_shuffled);
    }

    #[test]
    fn reduce_values_fuses_when_copartitioned_and_cuts_otherwise() {
        let s = sc();
        let d = Dataset::from_vec(&s, pairs(300, 11), 8).partition_by_key(8);
        let fused = d.lazy().map_values(|v| v + 1).reduce_values(8, |a, b| a + b);
        assert_eq!(fused.num_stages(), 1, "plan:\n{}", fused.explain());
        let cut = d.lazy().map(|r| (r.0, r.1)).reduce_values(8, |a, b| a + b);
        assert_eq!(cut.num_stages(), 2, "plan:\n{}", cut.explain());
        assert!(cut.explain().contains("[shuffle(aggregation)]"), "{}", cut.explain());
        // Both agree with the eager pipeline.
        let mut want = d.map_values(|v| v + 1).reduce_values(8, |a, b| a + b).collect();
        let mut got = fused.collect();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want);
        let mut got2 = cut.collect();
        got2.sort_unstable();
        assert_eq!(got2, want);
    }

    // ---- the double-count fix: rows charged once per stage, not per op ----

    #[test]
    fn fused_chain_scans_rows_once_not_once_per_op() {
        let s = sc();
        let n = 1000u64;
        let d = Dataset::from_vec(&s, (0..n).collect(), 8);
        let before = s.metrics().snapshot();
        let _ = d
            .lazy()
            .filter(|&x| x % 2 == 0)
            .map(|&x| x + 1)
            .map(|&x| x * 2)
            .materialize();
        let lazy = s.metrics().snapshot().since(&before);
        // The 3-op fused chain examines its input exactly once.
        assert_eq!(lazy.rows_scanned, n);
        assert_eq!(lazy.partitions_scanned, 8);
        assert_eq!(lazy.stages_run, 1);
        assert_eq!(lazy.ops_fused, 2);
        assert_eq!(lazy.intermediates_avoided, n / 2 + n / 2);
        assert_eq!(lazy.jobs, 1);
        // The eager chain charges every logical op's input — the
        // per-op double count the planner removes.
        let before = s.metrics().snapshot();
        let _ = d.filter(|&x| x % 2 == 0).map(|&x| x + 1).map(|&x| x * 2);
        let eager = s.metrics().snapshot().since(&before);
        assert_eq!(eager.rows_scanned, n + n / 2 + n / 2);
        assert_eq!(eager.stages_run, 0);
    }

    // ---- scheduler semantics ----

    #[test]
    fn materialize_is_memoized_and_extensions_restage() {
        let s = sc();
        let d = Dataset::from_vec(&s, (0..100u64).collect(), 4);
        let plan = d.lazy().filter(|&x| x < 50);
        let a = plan.materialize();
        let before = s.metrics().snapshot();
        let b = plan.materialize();
        assert_eq!(s.metrics().snapshot().since(&before).jobs, 0, "memoized");
        assert_eq!(a.collect(), b.collect());
        // Extending past a forced node starts a fresh stage over its output.
        let ext = plan.map(|&x| x + 1);
        let before = s.metrics().snapshot();
        let mut got = ext.collect();
        let delta = s.metrics().snapshot().since(&before);
        assert_eq!(delta.stages_run, 1);
        assert_eq!(delta.rows_scanned, 50, "restage scans the memoized output only");
        got.sort_unstable();
        assert_eq!(got, (1..=50).collect::<Vec<u64>>());
    }

    #[test]
    fn append_rows_fuses_and_meters_like_eager_append() {
        let s = sc();
        let base = Dataset::from_vec(&s, pairs(120, 9), 8).partition_by_key(8);
        let extra = pairs(30, 9);
        let before = s.metrics().snapshot();
        let lazy = base.lazy().append_rows(&extra).materialize();
        let dl = s.metrics().snapshot().since(&before);
        let before = s.metrics().snapshot();
        let eager = base.append_partitioned(&extra);
        let de = s.metrics().snapshot().since(&before);
        assert_eq!(dl.rows_shuffled, de.rows_shuffled, "append meters only new rows");
        assert_eq!(dl.rows_shuffled, extra.len() as u64);
        let (mut a, mut b) = (lazy.collect(), eager.collect());
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // The appended plan stays key-partitioned: a tagged re-partition
        // of either result is elided.
        let before = s.metrics().snapshot();
        let _ = lazy.partition_by_key(8);
        assert_eq!(s.metrics().snapshot().since(&before).shuffles_elided, 1);
    }

    #[test]
    fn counted_actions_report_deterministic_stage_costs() {
        let s = sc();
        let d = Dataset::from_vec(&s, (0..400u64).collect(), 8);
        let plan = d.lazy().filter(|&x| x % 4 == 0).map(|&x| x / 4);
        let (_, cold) = plan.materialize_counted();
        assert_eq!(cold.stages, 1);
        assert_eq!(cold.ops, 2);
        assert_eq!(cold.fused, 1);
        assert_eq!(cold.scan.partitions, 8);
        assert_eq!(cold.scan.rows, 400);
        assert_eq!(cold.intermediates_avoided, 100);
        // A memoized re-materialization replays the same cost even though
        // the engine ledger shows no new work — per-query attribution
        // stays deterministic under sharing.
        let before = s.metrics().snapshot();
        let (_, warm) = plan.materialize_counted();
        assert_eq!(warm, cold);
        assert_eq!(s.metrics().snapshot().since(&before).stages_run, 0);
    }

    #[test]
    fn elision_off_turns_tagged_repartition_into_a_cut() {
        let s = MiniSpark::new(ClusterConfig {
            executors: 4,
            default_partitions: 8,
            job_overhead_us: 0,
            shuffle_elision: false,
            ..Default::default()
        });
        let d = Dataset::from_vec(&s, pairs(100, 5), 8).partition_by_key(8);
        let plan = d.lazy().filter(|r| r.1 != 3).partition_by_key(8);
        assert_eq!(plan.num_stages(), 2, "plan:\n{}", plan.explain());
        let before = s.metrics().snapshot();
        let _ = plan.materialize();
        let delta = s.metrics().snapshot().since(&before);
        assert_eq!(delta.shuffles_elided, 0);
        assert!(delta.rows_shuffled > 0, "without elision the shuffle is real");
    }
}
