//! Engine metrics: cheap atomic counters capturing the data-volume costs
//! the paper reasons about (partitions scanned per lookup, triples recursed,
//! rows collected to the driver, jobs launched).

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic counters; shared by all datasets of one [`super::MiniSpark`].
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub jobs: AtomicU64,
    pub tasks: AtomicU64,
    pub partitions_scanned: AtomicU64,
    pub rows_scanned: AtomicU64,
    pub rows_shuffled: AtomicU64,
    pub rows_collected: AtomicU64,
    /// Shuffles skipped because the dataset was already partitioned on the
    /// requested key tag with the requested partition count.
    pub shuffles_elided: AtomicU64,
    /// Rows removed by map-side combining before the shuffle (input rows
    /// minus pre-aggregated rows actually moved).
    pub rows_combined: AtomicU64,
    /// Task attempts re-run by the supervisor after a caught panic (fault
    /// injection or a real bug; see `exec::par_map_supervised`).
    pub tasks_retried: AtomicU64,
    /// Partition-cache fetches served from resident memory.
    pub cache_hits: AtomicU64,
    /// Partition-cache fetches that had to page a segment in from disk.
    pub cache_misses: AtomicU64,
    /// Cache entries dropped to bring residency back under the byte budget.
    pub evictions: AtomicU64,
    /// Payload bytes written to segment files when datasets spilled.
    pub bytes_spilled: AtomicU64,
    /// Payload bytes read back from segment files on cache misses. For
    /// compressed (v5) sources this is the *on-disk* size — the real IO.
    pub bytes_paged_in: AtomicU64,
    /// Decoded in-memory bytes produced by cache misses. Equals
    /// `bytes_paged_in` for raw segments; the gap between the two is what
    /// the columnar encoding saved on the wire.
    pub bytes_decoded: AtomicU64,
    /// Disk bytes the columnar encoding avoided reading: decoded size minus
    /// on-disk size, accumulated across every compressed section loaded.
    pub bytes_compressed: AtomicU64,
    /// Partitions handed to the background readahead pool by frontier
    /// prefetch (whether or not the fetch won its race with demand).
    pub prefetch_issued: AtomicU64,
    /// Demand fetches served by a page a prefetch warmed. Each warmed page
    /// pays out at most once.
    pub prefetch_hits: AtomicU64,
    /// Fused stages executed by the lazy planner (see
    /// [`super::LazyDataset`]). Each stage is one pass over its input
    /// partitions no matter how many logical ops it fused.
    pub stages_run: AtomicU64,
    /// Logical narrow ops folded into an already-pending stage instead of
    /// running as their own pass (a 3-op fused chain counts 2).
    pub ops_fused: AtomicU64,
    /// Intermediate rows that eager execution would have materialized
    /// between fused ops but the pipelined stage never allocated.
    pub intermediates_avoided: AtomicU64,
}

/// A point-in-time copy of the counters, with subtraction for deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub tasks: u64,
    pub partitions_scanned: u64,
    pub rows_scanned: u64,
    pub rows_shuffled: u64,
    pub rows_collected: u64,
    pub shuffles_elided: u64,
    pub rows_combined: u64,
    pub tasks_retried: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub evictions: u64,
    pub bytes_spilled: u64,
    pub bytes_paged_in: u64,
    pub bytes_decoded: u64,
    pub bytes_compressed: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub stages_run: u64,
    pub ops_fused: u64,
    pub intermediates_avoided: u64,
}

impl EngineMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
            partitions_scanned: self.partitions_scanned.load(Ordering::Relaxed),
            rows_scanned: self.rows_scanned.load(Ordering::Relaxed),
            rows_shuffled: self.rows_shuffled.load(Ordering::Relaxed),
            rows_collected: self.rows_collected.load(Ordering::Relaxed),
            shuffles_elided: self.shuffles_elided.load(Ordering::Relaxed),
            rows_combined: self.rows_combined.load(Ordering::Relaxed),
            tasks_retried: self.tasks_retried.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            bytes_paged_in: self.bytes_paged_in.load(Ordering::Relaxed),
            bytes_decoded: self.bytes_decoded.load(Ordering::Relaxed),
            bytes_compressed: self.bytes_compressed.load(Ordering::Relaxed),
            prefetch_issued: self.prefetch_issued.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            stages_run: self.stages_run.load(Ordering::Relaxed),
            ops_fused: self.ops_fused.load(Ordering::Relaxed),
            intermediates_avoided: self.intermediates_avoided.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub fn add_job(&self) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_tasks(&self, n: u64) {
        self.tasks.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_scan(&self, partitions: u64, rows: u64) {
        self.partitions_scanned.fetch_add(partitions, Ordering::Relaxed);
        self.rows_scanned.fetch_add(rows, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_shuffled(&self, rows: u64) {
        self.rows_shuffled.fetch_add(rows, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_collected(&self, rows: u64) {
        self.rows_collected.fetch_add(rows, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_elided(&self) {
        self.shuffles_elided.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_combined(&self, rows: u64) {
        self.rows_combined.fetch_add(rows, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_tasks_retried(&self, n: u64) {
        self.tasks_retried.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_bytes_spilled(&self, bytes: u64) {
        self.bytes_spilled.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_bytes_paged_in(&self, bytes: u64) {
        self.bytes_paged_in.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_bytes_decoded(&self, bytes: u64) {
        self.bytes_decoded.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_bytes_compressed(&self, bytes: u64) {
        self.bytes_compressed.fetch_add(bytes, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_prefetch_issued(&self, n: u64) {
        self.prefetch_issued.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_prefetch_hit(&self) {
        self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One fused stage ran: `ops` logical ops in one pass, never allocating
    /// `intermediates` rows an eager chain would have materialized.
    #[inline]
    pub fn add_stage(&self, ops: u64, intermediates: u64) {
        self.stages_run.fetch_add(1, Ordering::Relaxed);
        self.ops_fused.fetch_add(ops.saturating_sub(1), Ordering::Relaxed);
        self.intermediates_avoided.fetch_add(intermediates, Ordering::Relaxed);
    }
}

impl MetricsSnapshot {
    /// Counter deltas since `earlier`.
    pub fn since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs - earlier.jobs,
            tasks: self.tasks - earlier.tasks,
            partitions_scanned: self.partitions_scanned - earlier.partitions_scanned,
            rows_scanned: self.rows_scanned - earlier.rows_scanned,
            rows_shuffled: self.rows_shuffled - earlier.rows_shuffled,
            rows_collected: self.rows_collected - earlier.rows_collected,
            shuffles_elided: self.shuffles_elided - earlier.shuffles_elided,
            rows_combined: self.rows_combined - earlier.rows_combined,
            tasks_retried: self.tasks_retried - earlier.tasks_retried,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            evictions: self.evictions - earlier.evictions,
            bytes_spilled: self.bytes_spilled - earlier.bytes_spilled,
            bytes_paged_in: self.bytes_paged_in - earlier.bytes_paged_in,
            bytes_decoded: self.bytes_decoded - earlier.bytes_decoded,
            bytes_compressed: self.bytes_compressed - earlier.bytes_compressed,
            prefetch_issued: self.prefetch_issued - earlier.prefetch_issued,
            prefetch_hits: self.prefetch_hits - earlier.prefetch_hits,
            stages_run: self.stages_run - earlier.stages_run,
            ops_fused: self.ops_fused - earlier.ops_fused,
            intermediates_avoided: self.intermediates_avoided - earlier.intermediates_avoided,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "jobs={} tasks={} parts_scanned={} rows_scanned={} shuffled={} collected={} \
             elided={} combined={} retried={} cache_hits={} cache_misses={} evictions={} \
             spilled={} paged_in={} decoded={} saved={} prefetch_issued={} prefetch_hits={} \
             stages={} fused={} intermediates_avoided={}",
            self.jobs,
            self.tasks,
            self.partitions_scanned,
            crate::util::fmt::human_count(self.rows_scanned),
            crate::util::fmt::human_count(self.rows_shuffled),
            crate::util::fmt::human_count(self.rows_collected),
            self.shuffles_elided,
            crate::util::fmt::human_count(self.rows_combined),
            self.tasks_retried,
            self.cache_hits,
            self.cache_misses,
            self.evictions,
            crate::util::fmt::human_bytes(self.bytes_spilled),
            crate::util::fmt::human_bytes(self.bytes_paged_in),
            crate::util::fmt::human_bytes(self.bytes_decoded),
            crate::util::fmt::human_bytes(self.bytes_compressed),
            self.prefetch_issued,
            self.prefetch_hits,
            self.stages_run,
            self.ops_fused,
            crate::util::fmt::human_count(self.intermediates_avoided),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_delta() {
        let m = EngineMetrics::default();
        m.add_job();
        m.add_scan(2, 100);
        let s1 = m.snapshot();
        m.add_job();
        m.add_scan(1, 50);
        m.add_collected(7);
        let d = m.snapshot().since(&s1);
        assert_eq!(d.jobs, 1);
        assert_eq!(d.partitions_scanned, 1);
        assert_eq!(d.rows_scanned, 50);
        assert_eq!(d.rows_collected, 7);
        assert!(d.summary().contains("jobs=1"));
    }

    #[test]
    fn io_pipeline_counters_snapshot_and_summarize() {
        let m = EngineMetrics::default();
        m.add_bytes_paged_in(100);
        m.add_bytes_decoded(400);
        m.add_bytes_compressed(300);
        m.add_prefetch_issued(5);
        m.add_prefetch_hit();
        let s = m.snapshot();
        assert_eq!(s.bytes_decoded, 400);
        assert_eq!(s.bytes_compressed, 300);
        assert_eq!((s.prefetch_issued, s.prefetch_hits), (5, 1));
        assert!(s.summary().contains("prefetch_issued=5"));
        assert!(s.summary().contains("prefetch_hits=1"));
    }

    #[test]
    fn stage_counters_fold_ops_and_intermediates() {
        let m = EngineMetrics::default();
        m.add_stage(3, 40); // 3 fused ops → 2 folded beyond the first
        m.add_stage(1, 0); // single-op stage fuses nothing
        let s = m.snapshot();
        assert_eq!(s.stages_run, 2);
        assert_eq!(s.ops_fused, 2);
        assert_eq!(s.intermediates_avoided, 40);
        assert!(s.summary().contains("stages=2"));
    }
}
